//! Pluggable compute backends for every [`CorpusView`](super::CorpusView)
//! scan (ADR-003).
//!
//! The hot path of the whole system is "score one query against a block of
//! corpus rows". This module owns that path behind the [`KernelBackend`]
//! trait with three implementations:
//!
//! - [`ScalarKernel`] — the canonical loops ([`dot_slice`] reduction
//!   order), the default.
//! - [`SimdKernel`] — AVX kernels that keep **one f64 lane per scalar
//!   accumulator** (`s0..s3` of the 4-way unroll map to the four lanes of a
//!   256-bit register, combined in the same `(s0+s1)+(s2+s3)` order), so
//!   results are *bit-identical* to [`ScalarKernel`]. Runtime CPU
//!   detection; scalar fallback on non-AVX hardware and non-x86 targets.
//! - [`QuantizedI8Kernel`] — scans a per-row symmetric i8 [`QuantSidecar`]
//!   with i32 accumulation as a *pre-filter*, then re-ranks survivors
//!   through the exact kernel, so final kNN/range results stay
//!   byte-identical to the exact backends (the certified error bound is
//!   derived in `interval_of`; see ADR-003 for the proof).
//!
//! Backends are selected per [`CorpusStore`](super::CorpusStore)
//! (`with_kernel` / `with_backend`), default to [`default_kernel`] (the
//! `SIMETRA_KERNEL` env var, else scalar), and are inherited by every view,
//! index, shard, and ingest generation built over the store.

use std::sync::{Arc, OnceLock};

use crate::index::KnnHeap;
use crate::obs::{Stage, TraceBuf, TraceEvent, OBS};
use crate::sync::{AtomicU64, Ordering::Relaxed};

use super::dot_slice;

/// Which backend a store scans with.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelKind {
    /// The canonical scalar loops (the default).
    Scalar,
    /// AVX f64-lane kernels, bit-identical to scalar, scalar fallback.
    Simd,
    /// i8 pre-filter + exact re-rank; exact results, fewer exact evals.
    QuantizedI8,
}

impl KernelKind {
    pub fn parse(s: &str) -> Option<KernelKind> {
        Some(match s.to_lowercase().as_str() {
            "scalar" => KernelKind::Scalar,
            "simd" => KernelKind::Simd,
            "i8" | "quantized" | "quantized-i8" => KernelKind::QuantizedI8,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Scalar => "scalar",
            KernelKind::Simd => "simd",
            KernelKind::QuantizedI8 => "i8",
        }
    }

    /// Validate a corpus dimension for this backend: the i8 kernel's i32
    /// accumulator bounds `d` by [`QUANT_MAX_DIM`]. Every config layer
    /// (CLI, coordinator, ingest) calls this for a clean error; paths that
    /// skip it degrade to exact scans (no sidecar is warmed) rather than
    /// panicking.
    pub fn validate_dim(self, d: usize) -> anyhow::Result<()> {
        if self == KernelKind::QuantizedI8 && d >= QUANT_MAX_DIM {
            anyhow::bail!("kernel i8 needs dim < {QUANT_MAX_DIM} (i32 accumulation); got {d}");
        }
        Ok(())
    }
}

/// Process-wide default backend kind: `SIMETRA_KERNEL` when set (`scalar`,
/// `simd`, or `i8` — how CI forces the whole test suite through a
/// backend), scalar otherwise. Read once and cached.
///
/// # Panics
/// Panics on an unparseable `SIMETRA_KERNEL` value — a misconfigured CI
/// matrix must fail loudly, not silently test the wrong backend.
pub fn default_kernel() -> KernelKind {
    static KIND: OnceLock<KernelKind> = OnceLock::new();
    *KIND.get_or_init(|| match std::env::var("SIMETRA_KERNEL") {
        Ok(v) => KernelKind::parse(&v)
            .unwrap_or_else(|| panic!("SIMETRA_KERNEL='{v}' is not scalar|simd|i8")),
        Err(_) => KernelKind::Scalar,
    })
}

/// A fresh backend instance (own counters) of the given kind.
pub fn backend_for(kind: KernelKind) -> Arc<dyn KernelBackend> {
    match kind {
        KernelKind::Scalar => Arc::new(ScalarKernel::default()),
        KernelKind::Simd => Arc::new(SimdKernel::new()),
        KernelKind::QuantizedI8 => Arc::new(QuantizedI8Kernel::new()),
    }
}

/// Lifetime counters of one backend instance (shared by every store clone
/// and view that scans through it; surfaced in `StatsSnapshot`).
#[derive(Debug, Default)]
pub struct KernelCounters {
    exact_rows: AtomicU64,
    quant_rows: AtomicU64,
    rerank_rows: AtomicU64,
}

impl KernelCounters {
    /// Rows scored exactly by the blocked scan entry points.
    pub fn blocked_scan_rows(&self) -> u64 {
        self.exact_rows.load(Relaxed)
    }

    /// Rows screened by the i8 pre-filter.
    pub fn quant_prefilter_rows(&self) -> u64 {
        self.quant_rows.load(Relaxed)
    }

    /// Pre-filter survivors re-ranked through the exact kernel.
    pub fn quant_rerank_rows(&self) -> u64 {
        self.rerank_rows.load(Relaxed)
    }
}

/// Sink for per-row similarities; invoked in ascending position order.
pub type SimSink<'a> = &'a mut dyn FnMut(usize, f64);

/// Sink for multi-query similarities: `(query slot, position, sim)`. For
/// each fixed slot, positions arrive in ascending order; the interleaving
/// across slots is backend-chosen (the consumers — per-slot heaps and
/// exact-checked range pushes — are insertion-order independent).
pub type MultiSimSink<'a> = &'a mut dyn FnMut(usize, usize, f64);

/// A batch of queries staged row-major in one flat f32 block — the
/// query-side operand of the (query-block × row-block) kernel calls
/// (ADR-006). Built once per batch from the individual query vectors; the
/// buffer is reused across batches, so steady-state staging allocates
/// nothing once warmed.
#[derive(Default)]
pub struct QueryBlock {
    flat: Vec<f32>,
    d: usize,
}

impl QueryBlock {
    /// Clear and set the dimension for a new batch (buffer kept).
    pub fn reset(&mut self, d: usize) {
        self.flat.clear();
        self.d = d;
    }

    /// Append one query row (must match the staged dimension).
    pub fn push(&mut self, q: &[f32]) {
        assert_eq!(q.len(), self.d, "QueryBlock: query dim {} != {}", q.len(), self.d);
        self.flat.extend_from_slice(q);
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    /// Number of staged queries.
    pub fn len(&self) -> usize {
        if self.d == 0 { 0 } else { self.flat.len() / self.d }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Query row `i`.
    #[inline]
    pub fn query(&self, i: usize) -> &[f32] {
        &self.flat[i * self.d..(i + 1) * self.d]
    }

    /// The whole staged block (row-major, `len() * dim()` floats).
    #[inline]
    pub fn as_flat(&self) -> &[f32] {
        &self.flat
    }
}

/// How the armed id filter of a [`KernelScratch`] interprets its id list
/// (ADR-005). Ids are in the *report-id* space of the scan — the same ids
/// a scan's heap offers / output pairs carry.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub enum FilterMode {
    /// No filter armed: every row is admitted.
    #[default]
    None,
    /// Only listed ids are admitted.
    Allow,
    /// Listed ids are excluded.
    Deny,
}

/// Quantized-query cache state of a [`KernelScratch`]. The `QuantQuery`
/// storage itself lives outside this tag so invalidation keeps the codes
/// buffer — a rebuilt query reuses it, and the steady-state query path
/// allocates nothing even under the i8 backend.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
enum QuantState {
    /// Nothing cached (fresh scratch, or invalidated by a new query).
    #[default]
    Empty,
    /// The scratch's `QuantQuery` holds the current query's quantized form.
    Built,
    /// The current query has a non-finite component: certified bounds are
    /// meaningless, every scan must take the exact path. Cached so the
    /// finiteness check also runs once per query, not once per leaf bucket.
    NonFinite,
}

/// Borrowed per-query scan scratch: the cached [`QuantQuery`] plus the
/// bound/survivor buffers the i8 pre-filter fills on every scan call.
///
/// One scratch lives in each `query::QueryContext` and is invalidated at
/// `begin_query`; the plain [`CorpusView`](super::CorpusView) scan entry
/// points construct a throwaway one per call (self-build, the pre-PR-4
/// behavior). The cache turns the i8 backend's per-leaf-bucket
/// re-quantization (O(d) + two allocations per scan call — the ROADMAP
/// follow-on) into one build per query regardless of how many buckets the
/// traversal scans.
///
/// Ownership contract (ADR-004): the cache is keyed by the query's
/// `(pointer, length)` identity *between invalidations*. A driver that
/// reuses a scratch across logical queries MUST call
/// `query::QueryContext::begin_query` (which calls [`KernelScratch::invalidate`])
/// at each query boundary; within one logical query the query slice must
/// stay alive and unmoved (true everywhere in this crate: the `DenseVec`
/// owning the query outlives the traversal).
#[derive(Default)]
pub struct KernelScratch {
    state: QuantState,
    /// Quantized-query storage, valid only while `state == Built`; its
    /// codes buffer survives invalidation, so rebuilds are allocation-free
    /// once warmed.
    qq: QuantQuery,
    /// `(ptr, len)` identity of the cached query.
    key: (usize, usize),
    /// Lifetime count of [`QuantQuery`] builds (the satellite's
    /// one-build-per-query assertion hangs off this).
    builds: u64,
    /// Certified bound buffers (i8 pre-filter).
    lb: Vec<f64>,
    ub: Vec<f64>,
    /// Survivor store rows + report ids (i8 re-rank gather).
    rows: Vec<u32>,
    ids: Vec<u32>,
    /// Armed per-request id filter (ADR-005): scans resolve their
    /// selection against it *before* any exact or quantized work, so
    /// filtered-out rows never cost an evaluation.
    filter_mode: FilterMode,
    filter_ids: Vec<u32>,
    /// Filtered-selection staging (store rows + report ids of admitted
    /// positions), reused across scan calls.
    frows: Vec<u32>,
    fids: Vec<u32>,
    /// Per-request kernel-backend override (ADR-005): `CorpusView` scans
    /// dispatch through this kind instead of the store's primary backend.
    kernel_override: Option<KernelKind>,
    /// Per-request EXPLAIN event log (ADR-007), armed by the plan layer;
    /// lives here so kernel scans can record their blocks directly.
    pub trace: TraceBuf,
    /// Whether aggregate observability (kernel-scan span timings) is on
    /// for the context owning this scratch (ADR-007).
    pub obs_enabled: bool,
    /// Debug builds keep the cached query's bytes so a cache hit can
    /// verify the `(ptr, len)` key really denotes the same query — an
    /// ABA'd address after a missed `invalidate` fails loudly in tests
    /// instead of silently pruning with another query's bounds.
    #[cfg(debug_assertions)]
    dbg_query: Vec<f32>,
}

impl KernelScratch {
    pub fn new() -> KernelScratch {
        KernelScratch::default()
    }

    /// Drop the cached quantized query (a new logical query begins). The
    /// underlying buffers are kept for reuse.
    pub fn invalidate(&mut self) {
        self.state = QuantState::Empty;
        self.key = (0, 0);
    }

    /// Lifetime number of quantized-query builds performed through this
    /// scratch. With a context reused correctly this is exactly one per
    /// distinct query that hit a quantized scan, however many leaf buckets
    /// each traversal scanned.
    pub fn quant_builds(&self) -> u64 {
        self.builds
    }

    /// Arm a per-request id filter for subsequent scans through this
    /// scratch. `ids` must arrive sorted ascending (the plan layer
    /// guarantees it); the list is copied into a reused buffer, so
    /// re-arming in the steady state allocates nothing.
    pub fn set_filter(&mut self, mode: FilterMode, ids: impl IntoIterator<Item = u32>) {
        self.filter_ids.clear();
        self.filter_ids.extend(ids);
        debug_assert!(self.filter_ids.windows(2).all(|w| w[0] <= w[1]), "filter ids not sorted");
        self.filter_mode = mode;
    }

    /// Disarm the id filter (the buffer is kept).
    pub fn clear_filter(&mut self) {
        self.filter_mode = FilterMode::None;
    }

    pub fn has_filter(&self) -> bool {
        self.filter_mode != FilterMode::None
    }

    /// Whether the armed filter admits report id `id` (`true` when no
    /// filter is armed).
    #[inline]
    pub fn filter_admits(&self, id: u32) -> bool {
        match self.filter_mode {
            FilterMode::None => true,
            FilterMode::Allow => self.filter_ids.binary_search(&id).is_ok(),
            FilterMode::Deny => self.filter_ids.binary_search(&id).is_err(),
        }
    }

    /// Arm / disarm the per-request kernel-backend override.
    pub fn set_kernel_override(&mut self, kind: Option<KernelKind>) {
        self.kernel_override = kind;
    }

    pub fn kernel_override(&self) -> Option<KernelKind> {
        self.kernel_override
    }

    /// Resolve `sel` against the armed filter: admitted positions are
    /// staged as `(absolute store rows, report ids)` in the scratch's
    /// reused buffers (taken, so the caller can hold a [`RowSel::Gather`]
    /// over them while still passing the scratch on mutably — pair with
    /// [`KernelScratch::restore_filter_bufs`]). `None` when no filter is
    /// armed.
    fn stage_filtered(&mut self, sel: &RowSel<'_>) -> Option<(Vec<u32>, Vec<u32>)> {
        if self.filter_mode == FilterMode::None {
            return None;
        }
        let mut rows = std::mem::take(&mut self.frows);
        let mut ids = std::mem::take(&mut self.fids);
        rows.clear();
        ids.clear();
        for pos in 0..sel.len() {
            let id = sel.report_id(pos);
            if self.filter_admits(id) {
                rows.push(sel.store_row(pos) as u32);
                ids.push(id);
            }
        }
        Some((rows, ids))
    }

    fn restore_filter_bufs(&mut self, (rows, ids): (Vec<u32>, Vec<u32>)) {
        self.frows = rows;
        self.fids = ids;
    }

    /// Make sure the cache holds the quantized form of `q`, building it if
    /// the scratch is empty or holds a different query.
    fn ensure_quant(&mut self, q: &[f32]) {
        let key = (q.as_ptr() as usize, q.len());
        if self.state == QuantState::Empty || self.key != key {
            self.builds += 1;
            if self.qq.rebuild(q) {
                self.state = QuantState::Built;
            } else {
                self.state = QuantState::NonFinite;
            }
            self.key = key;
            #[cfg(debug_assertions)]
            {
                self.dbg_query.clear();
                self.dbg_query.extend_from_slice(q);
            }
        }
        #[cfg(debug_assertions)]
        debug_assert!(
            self.dbg_query.iter().map(|v| v.to_bits()).eq(q.iter().map(|v| v.to_bits())),
            "KernelScratch cache hit for a different query: a driver reused \
             this scratch across logical queries without invalidate()/begin_query()"
        );
    }
}

/// Borrowed store state a scan needs: the flat buffer, the dimension, and
/// the quantized sidecar when the store carries one.
#[derive(Clone, Copy)]
pub struct StoreRef<'a> {
    pub flat: &'a [f32],
    pub d: usize,
    pub quant: Option<&'a QuantSidecar>,
}

/// Which store rows a scan covers, and the id reported for each position.
#[derive(Clone, Copy)]
pub enum RowSel<'a> {
    /// Store rows `start..start + n`; position `i` reports id `i`.
    Block { start: usize, n: usize },
    /// Store rows `base + rows[i]`; position `i` reports `report[i]`, or
    /// `i` itself when `report` is `None`.
    Gather { rows: &'a [u32], base: usize, report: Option<&'a [u32]> },
}

impl RowSel<'_> {
    pub fn len(&self) -> usize {
        match *self {
            RowSel::Block { n, .. } => n,
            RowSel::Gather { rows, .. } => rows.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Absolute store row backing position `pos`.
    pub fn store_row(&self, pos: usize) -> usize {
        match *self {
            RowSel::Block { start, .. } => start + pos,
            RowSel::Gather { rows, base, .. } => base + rows[pos] as usize,
        }
    }

    /// Id reported for position `pos`.
    pub fn report_id(&self, pos: usize) -> u32 {
        match *self {
            RowSel::Block { .. } => pos as u32,
            RowSel::Gather { report, .. } => report.map_or(pos as u32, |r| r[pos]),
        }
    }
}

/// One compute backend. Exactness contract (ADR-003): `sim_block` /
/// `sim_gather` are always exact and bit-identical to [`dot_slice`];
/// `scan_topk` / `scan_range` return results byte-identical to what the
/// exact scan would put in the heap / output vector — quantized backends
/// may skip rows, but only rows *certified* to miss the result set, and
/// every reported similarity comes from the exact kernel.
pub trait KernelBackend: Send + Sync {
    fn kind(&self) -> KernelKind;

    fn counters(&self) -> &KernelCounters;

    /// Exact sims of `q` against the `n` rows of a contiguous row-major
    /// `block` (`block.len() == n * d`), in ascending position order.
    fn sim_block(&self, q: &[f32], block: &[f32], d: usize, n: usize, sink: SimSink<'_>);

    /// Exact sims of `q` against store rows `base + rows[pos]` gathered
    /// from `flat`, in ascending position order.
    fn sim_gather(
        &self,
        q: &[f32],
        flat: &[f32],
        d: usize,
        rows: &[u32],
        base: usize,
        sink: SimSink<'_>,
    );

    /// Top-k scan over the selection; exact final results. Returns the
    /// number of exact similarity evaluations spent. `scratch` carries the
    /// per-query quantized-query cache and bound buffers (exact backends
    /// ignore it).
    fn scan_topk(
        &self,
        q: &[f32],
        s: StoreRef<'_>,
        sel: RowSel<'_>,
        heap: &mut KnnHeap,
        scratch: &mut KernelScratch,
    ) -> u64;

    /// Range scan (`sim >= tau`) over the selection, pushing `(id, sim)` in
    /// ascending position order; exact final results. Returns exact evals.
    fn scan_range(
        &self,
        q: &[f32],
        s: StoreRef<'_>,
        sel: RowSel<'_>,
        tau: f64,
        out: &mut Vec<(u32, f64)>,
        scratch: &mut KernelScratch,
    ) -> u64;

    /// Exact sims of every `live` query of the staged block against the
    /// selection — the (query-block × row-block) call of ADR-006. Every
    /// sim is bit-identical to [`dot_slice`], exactly like
    /// [`KernelBackend::sim_block`]; the default runs the canonical
    /// per-query loop, the SIMD backend re-uses each row block across
    /// queries.
    fn sim_block_multi(
        &self,
        qb: &QueryBlock,
        live: &[u32],
        s: StoreRef<'_>,
        sel: RowSel<'_>,
        sink: MultiSimSink<'_>,
    ) {
        for &j in live {
            let q = qb.query(j as usize);
            match sel {
                RowSel::Block { start, n } => {
                    let block = &s.flat[start * s.d..(start + n) * s.d];
                    self.sim_block(q, block, s.d, n, &mut |pos, sim| sink(j as usize, pos, sim));
                }
                RowSel::Gather { rows, base, .. } => {
                    self.sim_gather(q, s.flat, s.d, rows, base, &mut |pos, sim| {
                        sink(j as usize, pos, sim)
                    });
                }
            }
        }
    }

    /// Batched leaf scan with per-slot certified floors (the multi-query
    /// traversal's bucket visit): like [`KernelBackend::sim_block_multi`],
    /// but a backend may skip a `(slot, row)` pair when the row is
    /// *certified* to score strictly below `floors[slot]` — so skipped
    /// rows provably cannot change that slot's result set. Exact backends
    /// skip nothing; the quantized backend pre-filters per slot through
    /// one cached `QuantQuery` per slot (`scratches[slot]`), amortized
    /// across every row block of the batch. Returns exact evaluations
    /// (= sink invocations).
    // Wide by design: the multi-query kernel contract threads every
    // per-slot buffer through one call (ADR-006).
    #[allow(clippy::too_many_arguments)]
    fn scan_multi(
        &self,
        qb: &QueryBlock,
        live: &[u32],
        floors: &[f64],
        s: StoreRef<'_>,
        sel: RowSel<'_>,
        scratches: &mut [KernelScratch],
        sink: MultiSimSink<'_>,
    ) -> u64 {
        let _ = (floors, scratches);
        let n = sel.len() as u64;
        self.counters().exact_rows.fetch_add(live.len() as u64 * n, Relaxed);
        self.sim_block_multi(qb, live, s, sel, sink);
        live.len() as u64 * n
    }
}

/// The canonical scalar backend: today's loops, bit-for-bit.
#[derive(Debug, Default)]
pub struct ScalarKernel {
    counters: KernelCounters,
}

impl KernelBackend for ScalarKernel {
    fn kind(&self) -> KernelKind {
        KernelKind::Scalar
    }

    fn counters(&self) -> &KernelCounters {
        &self.counters
    }

    fn sim_block(&self, q: &[f32], block: &[f32], d: usize, n: usize, sink: SimSink<'_>) {
        sim_block_isa(Isa::Scalar, q, block, d, n, sink);
    }

    fn sim_gather(
        &self,
        q: &[f32],
        flat: &[f32],
        d: usize,
        rows: &[u32],
        base: usize,
        sink: SimSink<'_>,
    ) {
        sim_gather_isa(Isa::Scalar, q, flat, d, rows, base, sink);
    }

    fn scan_topk(
        &self,
        q: &[f32],
        s: StoreRef<'_>,
        sel: RowSel<'_>,
        heap: &mut KnnHeap,
        scratch: &mut KernelScratch,
    ) -> u64 {
        with_filtered_sel(scratch, sel, |_, sel| {
            exact_topk(Isa::Scalar, &self.counters, q, s, sel, heap)
        })
    }

    fn scan_range(
        &self,
        q: &[f32],
        s: StoreRef<'_>,
        sel: RowSel<'_>,
        tau: f64,
        out: &mut Vec<(u32, f64)>,
        scratch: &mut KernelScratch,
    ) -> u64 {
        with_filtered_sel(scratch, sel, |_, sel| {
            exact_range(Isa::Scalar, &self.counters, q, s, sel, tau, out)
        })
    }
}

/// The SIMD backend: AVX f64-lane kernels when the CPU has them, scalar
/// loops otherwise. Bit-identical to [`ScalarKernel`] either way.
#[derive(Debug)]
pub struct SimdKernel {
    isa: Isa,
    counters: KernelCounters,
}

impl SimdKernel {
    pub fn new() -> SimdKernel {
        SimdKernel { isa: detect_isa(), counters: KernelCounters::default() }
    }

    /// Whether the accelerated path is active (false = scalar fallback).
    pub fn accelerated(&self) -> bool {
        !matches!(self.isa, Isa::Scalar)
    }
}

impl Default for SimdKernel {
    fn default() -> Self {
        Self::new()
    }
}

impl KernelBackend for SimdKernel {
    fn kind(&self) -> KernelKind {
        KernelKind::Simd
    }

    fn counters(&self) -> &KernelCounters {
        &self.counters
    }

    fn sim_block(&self, q: &[f32], block: &[f32], d: usize, n: usize, sink: SimSink<'_>) {
        sim_block_isa(self.isa, q, block, d, n, sink);
    }

    fn sim_gather(
        &self,
        q: &[f32],
        flat: &[f32],
        d: usize,
        rows: &[u32],
        base: usize,
        sink: SimSink<'_>,
    ) {
        sim_gather_isa(self.isa, q, flat, d, rows, base, sink);
    }

    fn scan_topk(
        &self,
        q: &[f32],
        s: StoreRef<'_>,
        sel: RowSel<'_>,
        heap: &mut KnnHeap,
        scratch: &mut KernelScratch,
    ) -> u64 {
        with_filtered_sel(scratch, sel, |_, sel| {
            exact_topk(self.isa, &self.counters, q, s, sel, heap)
        })
    }

    fn scan_range(
        &self,
        q: &[f32],
        s: StoreRef<'_>,
        sel: RowSel<'_>,
        tau: f64,
        out: &mut Vec<(u32, f64)>,
        scratch: &mut KernelScratch,
    ) -> u64 {
        with_filtered_sel(scratch, sel, |_, sel| {
            exact_range(self.isa, &self.counters, q, s, sel, tau, out)
        })
    }

    fn sim_block_multi(
        &self,
        qb: &QueryBlock,
        live: &[u32],
        s: StoreRef<'_>,
        sel: RowSel<'_>,
        sink: MultiSimSink<'_>,
    ) {
        #[cfg(target_arch = "x86_64")]
        if let Isa::Avx = self.isa {
            assert_eq!(qb.dim(), s.d, "sim_block_multi: query dim {} != d={}", qb.dim(), s.d);
            match sel {
                RowSel::Block { start, n } => {
                    let block = &s.flat[start * s.d..(start + n) * s.d];
                    // SAFETY: `Isa::Avx` is only produced by `detect_isa`
                    // after a runtime AVX check, and the assert above pins
                    // every query row to exactly `d` elements.
                    unsafe { x86::block_multi_avx(qb.as_flat(), s.d, live, block, n, sink) };
                }
                // SAFETY: same AVX/dimension argument as the Block arm;
                // gathered row indices are bounds-checked against `flat`
                // inside the kernel.
                RowSel::Gather { rows, base, .. } => unsafe {
                    x86::gather_multi_avx(qb.as_flat(), s.d, live, s.flat, rows, base, sink)
                },
            }
            return;
        }
        exact_multi(Isa::Scalar, qb, live, s, sel, sink);
    }
}

/// The quantized backend: i8 pre-filter, exact re-rank. Exact primitives
/// (`sim_block` / `sim_gather`) go straight to the exact ISA path — only
/// the threshold/top-k scans, where a certified bound can prune, use the
/// sidecar.
#[derive(Debug)]
pub struct QuantizedI8Kernel {
    isa: Isa,
    counters: KernelCounters,
}

impl QuantizedI8Kernel {
    pub fn new() -> QuantizedI8Kernel {
        QuantizedI8Kernel { isa: detect_isa(), counters: KernelCounters::default() }
    }
}

impl Default for QuantizedI8Kernel {
    fn default() -> Self {
        Self::new()
    }
}

impl QuantizedI8Kernel {
    /// [`KernelBackend::scan_topk`] body after filter resolution.
    fn scan_topk_unfiltered(
        &self,
        q: &[f32],
        s: StoreRef<'_>,
        sel: RowSel<'_>,
        heap: &mut KnnHeap,
        scratch: &mut KernelScratch,
    ) -> u64 {
        let Some(quant) = s.quant else {
            // Store built without a sidecar: stay exact.
            return exact_topk(self.isa, &self.counters, q, s, sel, heap);
        };
        let n = sel.len();
        if n == 0 {
            return 0;
        }
        // One quantization per query, not per leaf bucket: reuse the
        // scratch's cached QuantQuery (built on the first scan this query
        // touches, identical bytes on every reuse).
        scratch.ensure_quant(q);
        let KernelScratch { state, qq, lb, ub, rows, ids, .. } = scratch;
        match state {
            QuantState::Built => {}
            // Non-finite query components make the certified bounds
            // meaningless; stay byte-identical to the exact backends.
            QuantState::NonFinite => {
                return exact_topk(self.isa, &self.counters, q, s, sel, heap)
            }
            QuantState::Empty => unreachable!("ensure_quant always fills the cache"),
        }
        self.counters.quant_rows.fetch_add(n as u64, Relaxed);
        // Certified pruning floor: the heap's exact floor, raised to the
        // k-th largest certified lower bound when enough candidates exist
        // (with fewer candidates than k the lower bounds can't raise it,
        // so don't compute them). Any row with ub < floor provably misses
        // the final top-k (its exact sim is strictly below the k-th best),
        // so skipping it keeps the heap byte-identical to the exact scan's.
        let mut floor = heap.floor();
        let k = heap.k();
        if n >= k {
            quant.intervals_into(qq, &sel, lb, ub);
            let (_, kth, _) = lb.select_nth_unstable_by(k - 1, |a, b| b.partial_cmp(a).unwrap());
            floor = floor.max(*kth);
        } else {
            quant.upper_bounds_into(qq, &sel, ub);
        }
        survivors_into(&sel, ub, floor, rows, ids);
        sim_gather_isa(self.isa, q, s.flat, s.d, rows, 0, &mut |i, sim| heap.offer(ids[i], sim));
        self.counters.rerank_rows.fetch_add(rows.len() as u64, Relaxed);
        rows.len() as u64
    }

    /// [`KernelBackend::scan_range`] body after filter resolution.
    fn scan_range_unfiltered(
        &self,
        q: &[f32],
        s: StoreRef<'_>,
        sel: RowSel<'_>,
        tau: f64,
        out: &mut Vec<(u32, f64)>,
        scratch: &mut KernelScratch,
    ) -> u64 {
        let Some(quant) = s.quant else {
            return exact_range(self.isa, &self.counters, q, s, sel, tau, out);
        };
        let n = sel.len();
        if n == 0 {
            return 0;
        }
        scratch.ensure_quant(q);
        let KernelScratch { state, qq, ub, rows, ids, .. } = scratch;
        match state {
            QuantState::Built => {}
            QuantState::NonFinite => {
                return exact_range(self.isa, &self.counters, q, s, sel, tau, out)
            }
            QuantState::Empty => unreachable!("ensure_quant always fills the cache"),
        }
        self.counters.quant_rows.fetch_add(n as u64, Relaxed);
        quant.upper_bounds_into(qq, &sel, ub);
        survivors_into(&sel, ub, tau, rows, ids);
        sim_gather_isa(self.isa, q, s.flat, s.d, rows, 0, &mut |i, sim| {
            if sim >= tau {
                out.push((ids[i], sim));
            }
        });
        self.counters.rerank_rows.fetch_add(rows.len() as u64, Relaxed);
        rows.len() as u64
    }
}

impl KernelBackend for QuantizedI8Kernel {
    fn kind(&self) -> KernelKind {
        KernelKind::QuantizedI8
    }

    fn counters(&self) -> &KernelCounters {
        &self.counters
    }

    fn sim_block(&self, q: &[f32], block: &[f32], d: usize, n: usize, sink: SimSink<'_>) {
        sim_block_isa(self.isa, q, block, d, n, sink);
    }

    fn sim_gather(
        &self,
        q: &[f32],
        flat: &[f32],
        d: usize,
        rows: &[u32],
        base: usize,
        sink: SimSink<'_>,
    ) {
        sim_gather_isa(self.isa, q, flat, d, rows, base, sink);
    }

    fn scan_topk(
        &self,
        q: &[f32],
        s: StoreRef<'_>,
        sel: RowSel<'_>,
        heap: &mut KnnHeap,
        scratch: &mut KernelScratch,
    ) -> u64 {
        with_filtered_sel(scratch, sel, |scratch, sel| {
            self.scan_topk_unfiltered(q, s, sel, heap, scratch)
        })
    }

    fn scan_range(
        &self,
        q: &[f32],
        s: StoreRef<'_>,
        sel: RowSel<'_>,
        tau: f64,
        out: &mut Vec<(u32, f64)>,
        scratch: &mut KernelScratch,
    ) -> u64 {
        with_filtered_sel(scratch, sel, |scratch, sel| {
            self.scan_range_unfiltered(q, s, sel, tau, out, scratch)
        })
    }

    // Wide by design: mirrors the kernel trait's multi-query contract
    // (ADR-006).
    #[allow(clippy::too_many_arguments)]
    fn scan_multi(
        &self,
        qb: &QueryBlock,
        live: &[u32],
        floors: &[f64],
        s: StoreRef<'_>,
        sel: RowSel<'_>,
        scratches: &mut [KernelScratch],
        sink: MultiSimSink<'_>,
    ) -> u64 {
        let n = sel.len();
        if n == 0 || live.is_empty() {
            return 0;
        }
        let Some(quant) = s.quant else {
            // Store built without a sidecar: stay exact.
            self.counters.exact_rows.fetch_add(live.len() as u64 * n as u64, Relaxed);
            exact_multi(self.isa, qb, live, s, sel, sink);
            return live.len() as u64 * n as u64;
        };
        let mut evals = 0u64;
        for &j in live {
            let q = qb.query(j as usize);
            let scratch = &mut scratches[j as usize];
            // One quantization per slot per batch, however many row blocks
            // the traversal visits: the slot's scratch caches the
            // QuantQuery exactly like the single-query path does per query.
            scratch.ensure_quant(q);
            let KernelScratch { state, qq, ub, rows, ids, .. } = scratch;
            match state {
                QuantState::Built => {}
                // Non-finite query components: certified bounds are
                // meaningless for this slot; score it exactly.
                QuantState::NonFinite => {
                    self.counters.exact_rows.fetch_add(n as u64, Relaxed);
                    exact_multi(self.isa, qb, &[j], s, sel, sink);
                    evals += n as u64;
                    continue;
                }
                QuantState::Empty => unreachable!("ensure_quant always fills the cache"),
            }
            self.counters.quant_rows.fetch_add(n as u64, Relaxed);
            quant.upper_bounds_into(qq, &sel, ub);
            // Survivors for this slot: rows below its certified floor are
            // provably outside its result set (exact sim <= ub < floor).
            // `ids` stages selection *positions* here, so the sink reports
            // in the same position space as the exact backends.
            rows.clear();
            ids.clear();
            for (pos, &u) in ub.iter().enumerate() {
                if u >= floors[j as usize] {
                    rows.push(sel.store_row(pos) as u32);
                    ids.push(pos as u32);
                }
            }
            sim_gather_isa(self.isa, q, s.flat, s.d, rows, 0, &mut |i, sim| {
                sink(j as usize, ids[i] as usize, sim)
            });
            self.counters.rerank_rows.fetch_add(rows.len() as u64, Relaxed);
            evals += rows.len() as u64;
        }
        evals
    }
}

// --- exact scan plumbing (shared by all backends) --------------------------

/// Resolve the scratch's armed id filter before running a scan body: with
/// no filter armed, `f` runs on `sel` unchanged; otherwise admitted
/// positions are staged as an explicit gather (absolute store rows +
/// report ids) and `f` scans only those — denied rows never reach an
/// exact or quantized evaluation, and every backend shares this one
/// resolution path. Being the one chokepoint every single-query scan goes
/// through, this is also where ADR-007 hooks live: a `Scan` trace event
/// (rows scanned, exact evals) when a trace is armed, and a `kernel_scan`
/// stage span when aggregate observability is on.
fn with_filtered_sel(
    scratch: &mut KernelScratch,
    sel: RowSel<'_>,
    f: impl FnOnce(&mut KernelScratch, RowSel<'_>) -> u64,
) -> u64 {
    let started = if scratch.obs_enabled { Some(std::time::Instant::now()) } else { None };
    let (evals, scanned) = match scratch.stage_filtered(&sel) {
        None => {
            let n = sel.len() as u64;
            (f(scratch, sel), n)
        }
        Some((rows, ids)) => {
            let n = rows.len() as u64;
            let out = f(scratch, RowSel::Gather { rows: &rows, base: 0, report: Some(&ids) });
            scratch.restore_filter_bufs((rows, ids));
            (out, n)
        }
    };
    scratch.trace.push(TraceEvent::scan(scanned, evals));
    if let Some(t0) = started {
        OBS.record_stage(Stage::KernelScan, t0.elapsed());
    }
    evals
}

fn exact_topk(
    isa: Isa,
    counters: &KernelCounters,
    q: &[f32],
    s: StoreRef<'_>,
    sel: RowSel<'_>,
    heap: &mut KnnHeap,
) -> u64 {
    let n = sel.len();
    counters.exact_rows.fetch_add(n as u64, Relaxed);
    match sel {
        RowSel::Block { start, n } => {
            let block = &s.flat[start * s.d..(start + n) * s.d];
            sim_block_isa(isa, q, block, s.d, n, &mut |pos, sim| heap.offer(pos as u32, sim));
        }
        RowSel::Gather { rows, base, report } => {
            sim_gather_isa(isa, q, s.flat, s.d, rows, base, &mut |pos, sim| {
                heap.offer(report.map_or(pos as u32, |r| r[pos]), sim)
            });
        }
    }
    n as u64
}

fn exact_range(
    isa: Isa,
    counters: &KernelCounters,
    q: &[f32],
    s: StoreRef<'_>,
    sel: RowSel<'_>,
    tau: f64,
    out: &mut Vec<(u32, f64)>,
) -> u64 {
    let n = sel.len();
    counters.exact_rows.fetch_add(n as u64, Relaxed);
    match sel {
        RowSel::Block { start, n } => {
            let block = &s.flat[start * s.d..(start + n) * s.d];
            sim_block_isa(isa, q, block, s.d, n, &mut |pos, sim| {
                if sim >= tau {
                    out.push((pos as u32, sim));
                }
            });
        }
        RowSel::Gather { rows, base, report } => {
            sim_gather_isa(isa, q, s.flat, s.d, rows, base, &mut |pos, sim| {
                if sim >= tau {
                    out.push((report.map_or(pos as u32, |r| r[pos]), sim));
                }
            });
        }
    }
    n as u64
}

/// Canonical multi-query exact scan: the per-query loop over the ISA
/// kernels (each slot's sims bit-identical to [`dot_slice`]). The scalar
/// backend's `sim_block_multi` default and every non-AVX fallback route
/// here.
fn exact_multi(
    isa: Isa,
    qb: &QueryBlock,
    live: &[u32],
    s: StoreRef<'_>,
    sel: RowSel<'_>,
    sink: MultiSimSink<'_>,
) {
    for &j in live {
        let q = qb.query(j as usize);
        match sel {
            RowSel::Block { start, n } => {
                let block = &s.flat[start * s.d..(start + n) * s.d];
                sim_block_isa(isa, q, block, s.d, n, &mut |pos, sim| sink(j as usize, pos, sim));
            }
            RowSel::Gather { rows, base, .. } => {
                sim_gather_isa(isa, q, s.flat, s.d, rows, base, &mut |pos, sim| {
                    sink(j as usize, pos, sim)
                });
            }
        }
    }
}

// --- ISA dispatch ----------------------------------------------------------

/// Instruction-set level the exact kernels run at.
#[derive(Debug, Clone, Copy)]
enum Isa {
    Scalar,
    #[cfg(target_arch = "x86_64")]
    Avx,
}

fn detect_isa() -> Isa {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx") {
            return Isa::Avx;
        }
    }
    Isa::Scalar
}

fn sim_block_isa(isa: Isa, q: &[f32], block: &[f32], d: usize, n: usize, sink: SimSink<'_>) {
    // Hard asserts, not debug_asserts: the AVX kernels derive loop trip
    // counts from q.len() and read row pointers d elements at a time, so a
    // mismatched query length must panic (as the scalar path does) rather
    // than read out of bounds in release builds.
    assert_eq!(q.len(), d, "sim_block: query dimension {} != d={d}", q.len());
    assert_eq!(block.len(), n * d, "sim_block: block length {} != n*d", block.len());
    match isa {
        Isa::Scalar => scalar_block(q, block, d, n, sink),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `Isa::Avx` is only produced by `detect_isa` after a
        // runtime AVX check, and the asserts above pin `q`/`block` lengths.
        Isa::Avx => unsafe { x86::block_avx(q, block, d, n, sink) },
    }
}

fn sim_gather_isa(
    isa: Isa,
    q: &[f32],
    flat: &[f32],
    d: usize,
    rows: &[u32],
    base: usize,
    sink: SimSink<'_>,
) {
    // See sim_block_isa: the row slices are bounds-checked against `flat`,
    // but the query length must equal d for the AVX loads to stay in-row.
    assert_eq!(q.len(), d, "sim_gather: query dimension {} != d={d}", q.len());
    match isa {
        Isa::Scalar => scalar_gather(q, flat, d, rows, base, sink),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `Isa::Avx` is only produced by `detect_isa` after a
        // runtime AVX check; `q.len() == d` is asserted above and row
        // slices are bounds-checked against `flat` inside the kernel.
        Isa::Avx => unsafe { x86::gather_avx(q, flat, d, rows, base, sink) },
    }
}

/// Positions whose certified upper bound clears `threshold`, resolved into
/// the scratch's `(absolute store rows, report ids)` buffers so the re-rank
/// can run through the batched gather kernel (query amortized over row
/// blocks, like every exact path) without allocating per scan.
fn survivors_into(
    sel: &RowSel<'_>,
    ub: &[f64],
    threshold: f64,
    rows: &mut Vec<u32>,
    ids: &mut Vec<u32>,
) {
    rows.clear();
    ids.clear();
    for (pos, &u) in ub.iter().enumerate() {
        if u >= threshold {
            rows.push(sel.store_row(pos) as u32);
            ids.push(sel.report_id(pos));
        }
    }
}

// --- scalar kernels --------------------------------------------------------

/// Two rows against one query in a single pass: the query stream is loaded
/// once and feeds two independent 4-way accumulator sets, replicating
/// [`dot_slice`]'s reduction order bit-for-bit for each row.
#[inline]
pub(crate) fn dot2(q: &[f32], r0: &[f32], r1: &[f32]) -> (f64, f64) {
    let n = q.len();
    debug_assert_eq!(r0.len(), n);
    debug_assert_eq!(r1.len(), n);
    let (r0, r1) = (&r0[..n], &r1[..n]);
    let chunks = n / 4;
    let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    let (mut b0, mut b1, mut b2, mut b3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    for i in 0..chunks {
        let j = i * 4;
        let (q0, q1, q2, q3) =
            (q[j] as f64, q[j + 1] as f64, q[j + 2] as f64, q[j + 3] as f64);
        a0 += q0 * r0[j] as f64;
        a1 += q1 * r0[j + 1] as f64;
        a2 += q2 * r0[j + 2] as f64;
        a3 += q3 * r0[j + 3] as f64;
        b0 += q0 * r1[j] as f64;
        b1 += q1 * r1[j + 1] as f64;
        b2 += q2 * r1[j + 2] as f64;
        b3 += q3 * r1[j + 3] as f64;
    }
    let mut sa = (a0 + a1) + (a2 + a3);
    let mut sb = (b0 + b1) + (b2 + b3);
    for j in chunks * 4..n {
        sa += q[j] as f64 * r0[j] as f64;
        sb += q[j] as f64 * r1[j] as f64;
    }
    (sa.clamp(-1.0, 1.0), sb.clamp(-1.0, 1.0))
}

fn scalar_block(q: &[f32], block: &[f32], d: usize, n: usize, sink: SimSink<'_>) {
    let mut i = 0usize;
    while i + 2 <= n {
        let b = i * d;
        let (s0, s1) = dot2(q, &block[b..b + d], &block[b + d..b + 2 * d]);
        sink(i, s0);
        sink(i + 1, s1);
        i += 2;
    }
    if i < n {
        sink(i, dot_slice(q, &block[i * d..(i + 1) * d]));
    }
}

fn scalar_gather(q: &[f32], flat: &[f32], d: usize, rows: &[u32], base: usize, sink: SimSink<'_>) {
    let row = |pos: usize| {
        let r = base + rows[pos] as usize;
        &flat[r * d..(r + 1) * d]
    };
    let mut i = 0usize;
    while i + 2 <= rows.len() {
        let (s0, s1) = dot2(q, row(i), row(i + 1));
        sink(i, s0);
        sink(i + 1, s1);
        i += 2;
    }
    if i < rows.len() {
        sink(i, dot_slice(q, row(i)));
    }
}

// --- AVX kernels (x86_64) --------------------------------------------------

/// Bit-exactness argument: [`dot_slice`](super::dot_slice) keeps four
/// independent f64 accumulators
/// `s0..s3`, each summing `q[4i+l] as f64 * r[4i+l] as f64`
/// sequentially, then combines `(s0+s1)+(s2+s3)`. The AVX kernels map
/// `s0..s3` onto the four lanes of a `__m256d`: each iteration widens four
/// f32s exactly (`vcvtps2pd`), multiplies, and adds — the same two IEEE
/// operations per lane in the same order, with no FMA contraction (the
/// intrinsics never fuse). The horizontal reduction extracts the lanes and
/// combines them in the scalar order, and the tail/clamp are shared with
/// the scalar code, so every similarity is bit-identical.
#[cfg(target_arch = "x86_64")]
mod x86 {
    // On toolchains with safe target-feature intrinsics (Rust 1.86+) the
    // register-only intrinsic calls below are safe when the enclosing fn
    // enables `avx`, so the explicit `unsafe {}` blocks — required by
    // `deny(unsafe_op_in_unsafe_fn)` on older toolchains — become
    // redundant and would trip `unused_unsafe`.
    #![allow(unused_unsafe)]

    use std::arch::x86_64::{
        __m256d, _mm256_add_pd, _mm256_castpd256_pd128, _mm256_cvtps_pd, _mm256_extractf128_pd,
        _mm256_mul_pd, _mm256_setzero_pd, _mm_cvtsd_f64, _mm_loadu_ps, _mm_unpackhi_pd,
    };

    use super::{MultiSimSink, SimSink};

    /// Widen 4 f32s at `p[j..j+4]` to f64 lanes.
    ///
    /// # Safety
    /// Requires `j + 4 <= p.len()` and the `avx` target feature.
    #[inline]
    #[target_feature(enable = "avx")]
    unsafe fn load4(p: &[f32], j: usize) -> __m256d {
        debug_assert!(j + 4 <= p.len());
        // SAFETY: the caller guarantees `j + 4 <= p.len()` (checked above
        // in debug builds), so the 16-byte unaligned load stays in bounds.
        unsafe { _mm256_cvtps_pd(_mm_loadu_ps(p.as_ptr().add(j))) }
    }

    /// Per-lane `acc + q * r` as separate mul/add (never fused).
    ///
    /// # Safety
    /// Requires the `avx` target feature.
    #[inline]
    #[target_feature(enable = "avx")]
    unsafe fn muladd(acc: __m256d, q: __m256d, r: __m256d) -> __m256d {
        // SAFETY: register-only arithmetic intrinsics; `avx` is enabled on
        // this fn and verified at runtime by the dispatcher.
        unsafe { _mm256_add_pd(acc, _mm256_mul_pd(q, r)) }
    }

    /// Combine lanes in the scalar order `(s0 + s1) + (s2 + s3)`.
    ///
    /// # Safety
    /// Requires the `avx` target feature.
    #[inline]
    #[target_feature(enable = "avx")]
    unsafe fn hsum(acc: __m256d) -> f64 {
        // SAFETY: register-only lane-extraction intrinsics; `avx` is
        // enabled on this fn and verified at runtime by the dispatcher.
        unsafe {
            let lo = _mm256_castpd256_pd128(acc);
            let hi = _mm256_extractf128_pd(acc, 1);
            let s0 = _mm_cvtsd_f64(lo);
            let s1 = _mm_cvtsd_f64(_mm_unpackhi_pd(lo, lo));
            let s2 = _mm_cvtsd_f64(hi);
            let s3 = _mm_cvtsd_f64(_mm_unpackhi_pd(hi, hi));
            (s0 + s1) + (s2 + s3)
        }
    }

    /// One row; bit-identical to [`dot_slice`].
    ///
    /// # Safety
    /// Requires the `avx` target feature; row length is asserted.
    #[target_feature(enable = "avx")]
    pub unsafe fn dot1(q: &[f32], r: &[f32]) -> f64 {
        let n = q.len();
        assert_eq!(r.len(), n, "dot1: dimension mismatch ({} vs {})", q.len(), r.len());
        let chunks = n / 4;
        // SAFETY: `r.len() == q.len()` is asserted above, so every
        // `load4(_, i * 4)` with `i < chunks` stays in bounds for both
        // slices; `avx` is enabled on this fn.
        let mut sum = unsafe {
            let mut acc = _mm256_setzero_pd();
            for i in 0..chunks {
                let j = i * 4;
                acc = muladd(acc, load4(q, j), load4(r, j));
            }
            hsum(acc)
        };
        for j in chunks * 4..n {
            sum += q[j] as f64 * r[j] as f64;
        }
        sum.clamp(-1.0, 1.0)
    }

    /// Two rows, query widened once per chunk.
    ///
    /// # Safety
    /// Requires the `avx` target feature and `r0.len() == r1.len() ==
    /// q.len()` (callers slice rows to exactly `d` elements).
    #[target_feature(enable = "avx")]
    unsafe fn dot2(q: &[f32], r0: &[f32], r1: &[f32]) -> (f64, f64) {
        let n = q.len();
        debug_assert_eq!(r0.len(), n);
        debug_assert_eq!(r1.len(), n);
        let chunks = n / 4;
        // SAFETY: rows are `n` long (caller contract, checked above in
        // debug builds), so each `load4` stays in bounds; `avx` is enabled
        // on this fn.
        let (mut sa, mut sb) = unsafe {
            let mut a = _mm256_setzero_pd();
            let mut b = _mm256_setzero_pd();
            for i in 0..chunks {
                let j = i * 4;
                let qv = load4(q, j);
                a = muladd(a, qv, load4(r0, j));
                b = muladd(b, qv, load4(r1, j));
            }
            (hsum(a), hsum(b))
        };
        for j in chunks * 4..n {
            sa += q[j] as f64 * r0[j] as f64;
            sb += q[j] as f64 * r1[j] as f64;
        }
        (sa.clamp(-1.0, 1.0), sb.clamp(-1.0, 1.0))
    }

    /// Four rows, query widened once per chunk.
    ///
    /// # Safety
    /// Requires the `avx` target feature and all four rows exactly
    /// `q.len()` elements (callers slice rows to exactly `d`).
    #[target_feature(enable = "avx")]
    unsafe fn dot4(
        q: &[f32],
        r0: &[f32],
        r1: &[f32],
        r2: &[f32],
        r3: &[f32],
    ) -> (f64, f64, f64, f64) {
        let n = q.len();
        let chunks = n / 4;
        // SAFETY: rows are `n` long (caller contract), so each `load4`
        // stays in bounds; `avx` is enabled on this fn.
        let (mut s0, mut s1, mut s2, mut s3) = unsafe {
            let mut a = _mm256_setzero_pd();
            let mut b = _mm256_setzero_pd();
            let mut c = _mm256_setzero_pd();
            let mut e = _mm256_setzero_pd();
            for i in 0..chunks {
                let j = i * 4;
                let qv = load4(q, j);
                a = muladd(a, qv, load4(r0, j));
                b = muladd(b, qv, load4(r1, j));
                c = muladd(c, qv, load4(r2, j));
                e = muladd(e, qv, load4(r3, j));
            }
            (hsum(a), hsum(b), hsum(c), hsum(e))
        };
        for j in chunks * 4..n {
            let qd = q[j] as f64;
            s0 += qd * r0[j] as f64;
            s1 += qd * r1[j] as f64;
            s2 += qd * r2[j] as f64;
            s3 += qd * r3[j] as f64;
        }
        (s0.clamp(-1.0, 1.0), s1.clamp(-1.0, 1.0), s2.clamp(-1.0, 1.0), s3.clamp(-1.0, 1.0))
    }

    /// # Safety
    /// Requires the `avx` target feature, `q.len() == d`, and
    /// `block.len() == n * d` (asserted by the dispatcher).
    #[target_feature(enable = "avx")]
    pub unsafe fn block_avx(q: &[f32], block: &[f32], d: usize, n: usize, sink: SimSink<'_>) {
        let mut i = 0usize;
        while i + 4 <= n {
            let b = i * d;
            // SAFETY: each row slice is exactly `d == q.len()` elements
            // (dispatcher-asserted); `avx` is enabled on this fn.
            let (s0, s1, s2, s3) = unsafe {
                dot4(
                    q,
                    &block[b..b + d],
                    &block[b + d..b + 2 * d],
                    &block[b + 2 * d..b + 3 * d],
                    &block[b + 3 * d..b + 4 * d],
                )
            };
            sink(i, s0);
            sink(i + 1, s1);
            sink(i + 2, s2);
            sink(i + 3, s3);
            i += 4;
        }
        while i + 2 <= n {
            let b = i * d;
            // SAFETY: as above — `d`-element row slices, `avx` enabled.
            let (s0, s1) = unsafe { dot2(q, &block[b..b + d], &block[b + d..b + 2 * d]) };
            sink(i, s0);
            sink(i + 1, s1);
            i += 2;
        }
        if i < n {
            // SAFETY: as above — `d`-element row slice, `avx` enabled.
            sink(i, unsafe { dot1(q, &block[i * d..(i + 1) * d]) });
        }
    }

    /// The blocked q×n microkernel (ADR-006): row-block outer, query
    /// inner, so each 4-row block is loaded from cache once and streamed
    /// against every live query. Per (query, row) the reduction is the
    /// same `dot4`/`dot2`/`dot1` the single-query kernel runs, so every
    /// sim stays bit-identical to the scalar path.
    ///
    /// # Safety
    /// Requires the `avx` target feature and `qs` packed as `d`-element
    /// query rows (dispatcher-asserted); `block`/`live` indexing is
    /// bounds-checked.
    #[target_feature(enable = "avx")]
    pub unsafe fn block_multi_avx(
        qs: &[f32],
        d: usize,
        live: &[u32],
        block: &[f32],
        n: usize,
        sink: MultiSimSink<'_>,
    ) {
        let q = |j: u32| &qs[j as usize * d..(j as usize + 1) * d];
        let mut i = 0usize;
        while i + 4 <= n {
            let b = i * d;
            let (r0, r1, r2, r3) = (
                &block[b..b + d],
                &block[b + d..b + 2 * d],
                &block[b + 2 * d..b + 3 * d],
                &block[b + 3 * d..b + 4 * d],
            );
            for &j in live {
                // SAFETY: query and row slices are exactly `d` elements;
                // `avx` is enabled on this fn.
                let (s0, s1, s2, s3) = unsafe { dot4(q(j), r0, r1, r2, r3) };
                sink(j as usize, i, s0);
                sink(j as usize, i + 1, s1);
                sink(j as usize, i + 2, s2);
                sink(j as usize, i + 3, s3);
            }
            i += 4;
        }
        while i + 2 <= n {
            let b = i * d;
            let (r0, r1) = (&block[b..b + d], &block[b + d..b + 2 * d]);
            for &j in live {
                // SAFETY: as above — `d`-element slices, `avx` enabled.
                let (s0, s1) = unsafe { dot2(q(j), r0, r1) };
                sink(j as usize, i, s0);
                sink(j as usize, i + 1, s1);
            }
            i += 2;
        }
        if i < n {
            let r = &block[i * d..(i + 1) * d];
            for &j in live {
                // SAFETY: as above — `d`-element slices, `avx` enabled.
                sink(j as usize, i, unsafe { dot1(q(j), r) });
            }
        }
    }

    /// Gather form of [`block_multi_avx`]: same row-block-outer shape over
    /// gathered rows.
    ///
    /// # Safety
    /// Requires the `avx` target feature and `qs` packed as `d`-element
    /// query rows (dispatcher-asserted); gathered rows are bounds-checked
    /// against `flat`.
    #[target_feature(enable = "avx")]
    pub unsafe fn gather_multi_avx(
        qs: &[f32],
        d: usize,
        live: &[u32],
        flat: &[f32],
        rows: &[u32],
        base: usize,
        sink: MultiSimSink<'_>,
    ) {
        let q = |j: u32| &qs[j as usize * d..(j as usize + 1) * d];
        let row = |pos: usize| {
            let r = base + rows[pos] as usize;
            &flat[r * d..(r + 1) * d]
        };
        let mut i = 0usize;
        while i + 4 <= rows.len() {
            let (r0, r1, r2, r3) = (row(i), row(i + 1), row(i + 2), row(i + 3));
            for &j in live {
                // SAFETY: query and row slices are exactly `d` elements;
                // `avx` is enabled on this fn.
                let (s0, s1, s2, s3) = unsafe { dot4(q(j), r0, r1, r2, r3) };
                sink(j as usize, i, s0);
                sink(j as usize, i + 1, s1);
                sink(j as usize, i + 2, s2);
                sink(j as usize, i + 3, s3);
            }
            i += 4;
        }
        while i + 2 <= rows.len() {
            let (r0, r1) = (row(i), row(i + 1));
            for &j in live {
                // SAFETY: as above — `d`-element slices, `avx` enabled.
                let (s0, s1) = unsafe { dot2(q(j), r0, r1) };
                sink(j as usize, i, s0);
                sink(j as usize, i + 1, s1);
            }
            i += 2;
        }
        if i < rows.len() {
            let r = row(i);
            for &j in live {
                // SAFETY: as above — `d`-element slices, `avx` enabled.
                sink(j as usize, i, unsafe { dot1(q(j), r) });
            }
        }
    }

    /// # Safety
    /// Requires the `avx` target feature and `q.len() == d`
    /// (dispatcher-asserted); gathered rows are bounds-checked against
    /// `flat`.
    #[target_feature(enable = "avx")]
    pub unsafe fn gather_avx(
        q: &[f32],
        flat: &[f32],
        d: usize,
        rows: &[u32],
        base: usize,
        sink: SimSink<'_>,
    ) {
        let row = |pos: usize| {
            let r = base + rows[pos] as usize;
            &flat[r * d..(r + 1) * d]
        };
        let mut i = 0usize;
        while i + 4 <= rows.len() {
            // SAFETY: row slices are exactly `d == q.len()` elements;
            // `avx` is enabled on this fn.
            let (s0, s1, s2, s3) = unsafe { dot4(q, row(i), row(i + 1), row(i + 2), row(i + 3)) };
            sink(i, s0);
            sink(i + 1, s1);
            sink(i + 2, s2);
            sink(i + 3, s3);
            i += 4;
        }
        while i + 2 <= rows.len() {
            // SAFETY: as above — `d`-element row slices, `avx` enabled.
            let (s0, s1) = unsafe { dot2(q, row(i), row(i + 1)) };
            sink(i, s0);
            sink(i + 1, s1);
            i += 2;
        }
        if i < rows.len() {
            // SAFETY: as above — `d`-element row slice, `avx` enabled.
            sink(i, unsafe { dot1(q, row(i)) });
        }
    }
}

// --- i8 quantization -------------------------------------------------------

/// Multiplicative and additive slack on the certified error bound,
/// covering f64 roundoff in the bound computation itself. The analytic
/// bound is exact in real arithmetic; evaluating it in f64 over d <= 100k
/// terms has relative error < 1e-11, so this margin is generous.
const EPS_REL: f64 = 1.0 + 1e-6;
const EPS_ABS: f64 = 1e-12;

/// Largest dimension the i8 kernel accepts: the i32 dot accumulator needs
/// `d * 127^2 < i32::MAX`. The CLI and the coordinator/ingest config
/// layers reject larger dims with a clean error ([`KernelKind::validate_dim`]);
/// warm points refuse to build an oversized sidecar, so unvalidated paths
/// degrade to exact scans instead of panicking.
pub const QUANT_MAX_DIM: usize = 100_000;

/// Stores smaller than this scan exactly even under the i8 backend —
/// `warm_quant_sidecar` refuses to build. Below this size the pre-filter
/// cannot save enough exact evaluations to pay for itself. (The ingest
/// memtable never builds a sidecar at *any* size: sidecars are built only
/// at explicit warm points, never by a scan.)
pub const QUANT_MIN_ROWS: usize = 1024;

/// Per-row symmetric i8 quantization of a store buffer: `codes[row*d + j]
/// = round(flat[row*d + j] / scale[row])` with `scale[row] =
/// max_j |flat[row*d + j]| / 127`. Stored next to the f32 buffer; the f32
/// rows remain the source of truth for every reported similarity.
pub struct QuantSidecar {
    codes: Vec<i8>,
    scale: Vec<f64>,
    /// Per-row L1 norm of the *original* f32 row (for the error bound).
    l1: Vec<f64>,
    d: usize,
}

impl QuantSidecar {
    pub fn build(flat: &[f32], d: usize) -> QuantSidecar {
        // i32 accumulation: |code| <= 127, so d products fit while
        // d * 127^2 < i32::MAX.
        assert!(d < QUANT_MAX_DIM, "i8 kernel needs d < {QUANT_MAX_DIM} for i32 accumulation");
        if d == 0 {
            return QuantSidecar { codes: Vec::new(), scale: Vec::new(), l1: Vec::new(), d };
        }
        let n = flat.len() / d;
        let mut codes = Vec::with_capacity(n * d);
        let mut scale = Vec::with_capacity(n);
        let mut l1 = Vec::with_capacity(n);
        for row in flat.chunks_exact(d) {
            // A non-finite component would poison the certified bounds
            // (NaN-absorbing min/max invert the interval); give such rows
            // an infinite error bound instead, so they always survive the
            // pre-filter and are scored exactly — byte-identical results,
            // like the query-side fallback in `QuantQuery::build`.
            let finite = row.iter().all(|v| v.is_finite());
            let max = if finite {
                row.iter().fold(0.0f64, |m, &v| m.max((v as f64).abs()))
            } else {
                0.0
            };
            let s = max / 127.0;
            let mut a1 = 0.0f64;
            if s > 0.0 {
                for &v in row {
                    a1 += (v as f64).abs();
                    codes.push((v as f64 / s).round().clamp(-127.0, 127.0) as i8);
                }
            } else {
                codes.resize(codes.len() + d, 0);
            }
            scale.push(s);
            l1.push(if finite { a1 } else { f64::INFINITY });
        }
        QuantSidecar { codes, scale, l1, d }
    }

    /// Dequantization scale of `row`.
    pub fn scale(&self, row: usize) -> f64 {
        self.scale[row]
    }

    /// Quantized codes of `row`.
    pub fn codes(&self, row: usize) -> &[i8] {
        &self.codes[row * self.d..(row + 1) * self.d]
    }

    /// Certified `(approx, eps)` for one store row: the quantized
    /// similarity estimate and its error bound.
    ///
    /// Bound: with `q~ = sq * cq` and `r~ = sr * cr` the dequantized
    /// vectors, `|q.r - q~.r~| <= (sq/2)*||r||_1 + (sr/2)*||q~||_1`
    /// (triangle inequality over the per-component rounding errors).
    fn interval_of(&self, qq: &QuantQuery, row: usize) -> (f64, f64) {
        let l1r = self.l1[row];
        if !l1r.is_finite() {
            // Non-finite row (see `build`): certify nothing — an infinite
            // bound keeps the row in every survivor set. (Computed inline,
            // a zero query scale times this infinity would be NaN.)
            return (0.0, f64::INFINITY);
        }
        let codes = self.codes(row);
        let mut acc = 0i32;
        for (&a, &b) in qq.codes.iter().zip(codes) {
            acc += a as i32 * b as i32;
        }
        let approx = qq.scale * self.scale[row] * acc as f64;
        let raw = 0.5 * qq.scale * l1r + 0.5 * self.scale[row] * qq.l1_deq;
        (approx, raw * EPS_REL + EPS_ABS)
    }

    /// Certified `[approx - eps, approx + eps]` similarity intervals of the
    /// quantized query against every selected row, replacing the contents
    /// of the borrowed scratch buffers. The exact similarity additionally
    /// clamps to `[-1, 1]`, so the interval edges clamp one-sidedly too.
    fn intervals_into(
        &self,
        qq: &QuantQuery,
        sel: &RowSel<'_>,
        lb: &mut Vec<f64>,
        ub: &mut Vec<f64>,
    ) {
        let n = sel.len();
        lb.clear();
        ub.clear();
        lb.reserve(n);
        ub.reserve(n);
        for pos in 0..n {
            let (approx, eps) = self.interval_of(qq, sel.store_row(pos));
            lb.push((approx - eps).min(1.0));
            ub.push((approx + eps).max(-1.0));
        }
    }

    /// Upper interval edges only (range scans never need the lower edge).
    fn upper_bounds_into(&self, qq: &QuantQuery, sel: &RowSel<'_>, ub: &mut Vec<f64>) {
        let n = sel.len();
        ub.clear();
        ub.reserve(n);
        for pos in 0..n {
            let (approx, eps) = self.interval_of(qq, sel.store_row(pos));
            ub.push((approx + eps).max(-1.0));
        }
    }
}

/// A query quantized once per query (cached in [`KernelScratch`]; the
/// storage is reused across queries, so rebuilds stop allocating once the
/// codes buffer has grown to the corpus dimension).
#[derive(Default)]
struct QuantQuery {
    codes: Vec<i8>,
    scale: f64,
    /// L1 norm of the *dequantized* query (for the error bound).
    l1_deq: f64,
}

impl QuantQuery {
    /// Re-quantize in place for a new query, reusing the codes buffer.
    /// Returns `false` when any component is non-finite — the error bound
    /// is meaningless then, and the caller must take the exact path to
    /// stay byte-identical to the exact backends (`self` is left cleared).
    fn rebuild(&mut self, q: &[f32]) -> bool {
        self.codes.clear();
        self.scale = 0.0;
        self.l1_deq = 0.0;
        let mut max = 0.0f64;
        for &v in q {
            if !v.is_finite() {
                return false;
            }
            max = max.max((v as f64).abs());
        }
        let scale = max / 127.0;
        if scale == 0.0 {
            self.codes.resize(q.len(), 0);
            return true;
        }
        let mut code_l1 = 0.0f64;
        self.codes.reserve(q.len());
        for &v in q {
            let c = (v as f64 / scale).round().clamp(-127.0, 127.0);
            code_l1 += c.abs();
            self.codes.push(c as i8);
        }
        self.scale = scale;
        self.l1_deq = scale * code_l1;
        true
    }

    /// Owned build, `None` on a non-finite component (test helper; the
    /// production path goes through [`KernelScratch::ensure_quant`]).
    #[cfg(test)]
    fn build(q: &[f32]) -> Option<QuantQuery> {
        let mut qq = QuantQuery::default();
        if qq.rebuild(q) { Some(qq) } else { None }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::uniform_sphere;

    #[test]
    fn kernel_kind_parses_and_names() {
        assert_eq!(KernelKind::parse("scalar"), Some(KernelKind::Scalar));
        assert_eq!(KernelKind::parse("SIMD"), Some(KernelKind::Simd));
        assert_eq!(KernelKind::parse("i8"), Some(KernelKind::QuantizedI8));
        assert_eq!(KernelKind::parse("quantized"), Some(KernelKind::QuantizedI8));
        assert_eq!(KernelKind::parse("bogus"), None);
        assert_eq!(KernelKind::QuantizedI8.name(), "i8");
    }

    #[test]
    fn simd_rows_match_scalar_bitwise() {
        // Straddle the 4-row block, the pair, and the 4-lane chunk
        // boundaries, with tails.
        for (n, d) in [(1usize, 3usize), (2, 4), (5, 7), (8, 8), (9, 13), (33, 17), (64, 96)] {
            let rows = uniform_sphere(n, d, 7 + n as u64);
            let mut flat = Vec::new();
            for r in &rows {
                flat.extend_from_slice(r.as_slice());
            }
            let q = uniform_sphere(1, d, 999).pop().unwrap();
            let scalar = ScalarKernel::default();
            let simd = SimdKernel::new();
            let mut a = Vec::new();
            let mut b = Vec::new();
            scalar.sim_block(q.as_slice(), &flat, d, n, &mut |pos, s| a.push((pos, s)));
            simd.sim_block(q.as_slice(), &flat, d, n, &mut |pos, s| b.push((pos, s)));
            assert_eq!(a.len(), b.len());
            for ((pa, sa), (pb, sb)) in a.iter().zip(&b) {
                assert_eq!(pa, pb);
                assert_eq!(sa.to_bits(), sb.to_bits(), "n={n} d={d} pos={pa}");
            }
        }
    }

    #[test]
    fn quant_sidecar_roundtrip_error_is_bounded() {
        let rows = uniform_sphere(40, 33, 11);
        let mut flat = Vec::new();
        for r in &rows {
            flat.extend_from_slice(r.as_slice());
        }
        let side = QuantSidecar::build(&flat, 33);
        for (i, r) in rows.iter().enumerate() {
            let s = side.scale(i);
            let codes = side.codes(i);
            for (j, &v) in r.as_slice().iter().enumerate() {
                let deq = s * codes[j] as f64;
                // Unit-norm rows have max |component| <= 1, so the
                // per-component rounding error is <= scale/2 <= 1/254.
                assert!(
                    (v as f64 - deq).abs() <= 1.0 / 127.0,
                    "row {i} component {j}: {v} vs {deq}"
                );
                assert!((v as f64 - deq).abs() <= s * 0.5 + 1e-12);
            }
        }
    }

    #[test]
    fn quant_intervals_contain_the_exact_similarity() {
        let d = 19;
        let rows = uniform_sphere(64, d, 3);
        let mut flat = Vec::new();
        for r in &rows {
            flat.extend_from_slice(r.as_slice());
        }
        let side = QuantSidecar::build(&flat, d);
        for qs in 0..4u64 {
            let q = uniform_sphere(1, d, 100 + qs).pop().unwrap();
            let qq = QuantQuery::build(q.as_slice()).unwrap();
            let sel = RowSel::Block { start: 0, n: rows.len() };
            let (mut lb, mut ub) = (Vec::new(), Vec::new());
            side.intervals_into(&qq, &sel, &mut lb, &mut ub);
            for (i, r) in rows.iter().enumerate() {
                let exact = dot_slice(q.as_slice(), r.as_slice());
                assert!(
                    lb[i] <= exact && exact <= ub[i],
                    "row {i}: {exact} not in [{}, {}]",
                    lb[i],
                    ub[i]
                );
            }
        }
    }

    #[test]
    fn zero_query_and_zero_rows_quantize_safely() {
        let d = 8;
        let flat = [0.0f32; 16];
        let side = QuantSidecar::build(&flat, d);
        assert_eq!(side.scale(0), 0.0);
        let zeros = [0.0f32; 8];
        let qq = QuantQuery::build(&zeros).unwrap();
        let (mut lb, mut ub) = (Vec::new(), Vec::new());
        side.intervals_into(&qq, &RowSel::Block { start: 0, n: 2 }, &mut lb, &mut ub);
        assert!(lb[0] <= 0.0 && 0.0 <= ub[0]);
        assert!(lb[1] <= 0.0 && 0.0 <= ub[1]);
    }

    #[test]
    fn non_finite_rows_always_survive_the_prefilter() {
        let rows = uniform_sphere(4, 6, 31);
        let mut flat = Vec::new();
        for r in &rows {
            flat.extend_from_slice(r.as_slice());
        }
        flat[7] = f32::NAN; // corrupt one component of row 1
        let side = QuantSidecar::build(&flat, 6);
        let q = uniform_sphere(1, 6, 99).pop().unwrap();
        let qq = QuantQuery::build(q.as_slice()).unwrap();
        let (mut lb, mut ub) = (Vec::new(), Vec::new());
        side.intervals_into(&qq, &RowSel::Block { start: 0, n: 4 }, &mut lb, &mut ub);
        // The corrupted row certifies nothing: it can never be pruned and
        // never raises the floor.
        assert_eq!(ub[1], f64::INFINITY);
        assert_eq!(lb[1], f64::NEG_INFINITY);
        // Finite rows still get finite certified intervals.
        assert!(ub[0].is_finite() && lb[0].is_finite());
    }

    #[test]
    fn shared_scratch_quantizes_once_per_query_across_scan_calls() {
        // One QuantQuery build per query however many leaf-bucket scans the
        // traversal issues (the ROADMAP follow-on this PR closes), and the
        // results stay byte-identical to per-call self-building.
        let d = 12;
        let rows = uniform_sphere(64, d, 51);
        let mut flat = Vec::new();
        for r in &rows {
            flat.extend_from_slice(r.as_slice());
        }
        let side = QuantSidecar::build(&flat, d);
        let sref = StoreRef { flat: &flat, d, quant: Some(&side) };
        let kernel = QuantizedI8Kernel::new();
        let q = uniform_sphere(1, d, 77).pop().unwrap();

        let mut shared = KernelScratch::new();
        let mut h_shared = KnnHeap::new(4);
        let mut h_fresh = KnnHeap::new(4);
        let mut out_shared = Vec::new();
        let mut out_fresh = Vec::new();
        // 16 bucket-like scans of 4 rows each, alternating topk and range.
        for b in 0..16usize {
            let sel = RowSel::Block { start: b * 4, n: 4 };
            kernel.scan_topk(q.as_slice(), sref, sel, &mut h_shared, &mut shared);
            kernel.scan_topk(q.as_slice(), sref, sel, &mut h_fresh, &mut KernelScratch::new());
            kernel.scan_range(q.as_slice(), sref, sel, 0.1, &mut out_shared, &mut shared);
            kernel.scan_range(
                q.as_slice(),
                sref,
                sel,
                0.1,
                &mut out_fresh,
                &mut KernelScratch::new(),
            );
        }
        assert_eq!(shared.quant_builds(), 1, "one build per query, not per scan call");
        assert_eq!(out_shared, out_fresh);
        let (a, b) = (h_shared.into_sorted(), h_fresh.into_sorted());
        assert_eq!(a, b);

        // A new query through the same scratch re-builds exactly once; an
        // explicit invalidate (the begin_query hook) also forces a build.
        let q2 = uniform_sphere(1, d, 78).pop().unwrap();
        let mut h2 = KnnHeap::new(4);
        let sel = RowSel::Block { start: 0, n: 64 };
        kernel.scan_topk(q2.as_slice(), sref, sel, &mut h2, &mut shared);
        kernel.scan_topk(q2.as_slice(), sref, sel, &mut h2, &mut shared);
        assert_eq!(shared.quant_builds(), 2);
        shared.invalidate();
        kernel.scan_topk(q2.as_slice(), sref, sel, &mut h2, &mut shared);
        assert_eq!(shared.quant_builds(), 3);
    }

    #[test]
    fn multi_kernels_match_per_query_bitwise() {
        // Straddle the 4-row block, pair, and tail boundaries; exercise a
        // live list with a hole so skipped slots truly see no sims.
        for (n, d) in [(5usize, 7usize), (9, 13), (33, 17), (64, 32)] {
            let rows = uniform_sphere(n, d, 7 + n as u64);
            let mut flat = Vec::new();
            for r in &rows {
                flat.extend_from_slice(r.as_slice());
            }
            let queries = uniform_sphere(5, d, 1000 + n as u64);
            let mut qb = QueryBlock::default();
            qb.reset(d);
            for q in &queries {
                qb.push(q.as_slice());
            }
            assert_eq!(qb.len(), 5);
            let live = [0u32, 2, 3, 4];
            let gather: Vec<u32> = (0..n as u32).rev().collect();
            let sref = StoreRef { flat: &flat, d, quant: None };
            for kind in [KernelKind::Scalar, KernelKind::Simd, KernelKind::QuantizedI8] {
                let backend = backend_for(kind);
                let sels = [
                    RowSel::Block { start: 0, n },
                    RowSel::Gather { rows: &gather, base: 0, report: None },
                ];
                for sel in sels {
                    let mut got: Vec<Vec<(usize, f64)>> = vec![Vec::new(); 5];
                    backend.sim_block_multi(&qb, &live, sref, sel, &mut |j, pos, sim| {
                        got[j].push((pos, sim))
                    });
                    assert!(got[1].is_empty(), "slot 1 is not live");
                    for &j in &live {
                        let q = queries[j as usize].as_slice();
                        let mut want: Vec<(usize, f64)> = Vec::new();
                        match sel {
                            RowSel::Block { .. } => backend
                                .sim_block(q, &flat, d, n, &mut |pos, s| want.push((pos, s))),
                            RowSel::Gather { .. } => backend
                                .sim_gather(q, &flat, d, &gather, 0, &mut |pos, s| {
                                    want.push((pos, s))
                                }),
                        }
                        assert_eq!(got[j as usize].len(), want.len());
                        for (a, b) in got[j as usize].iter().zip(&want) {
                            assert_eq!(a.0, b.0, "{} n={n} d={d}", kind.name());
                            assert_eq!(a.1.to_bits(), b.1.to_bits(), "{} n={n} d={d}", kind.name());
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn i8_scan_multi_prefilters_exactly_and_quantizes_once_per_slot() {
        let (n, d, q_count, k) = (128usize, 16usize, 4usize, 4usize);
        let rows = uniform_sphere(n, d, 41);
        let mut flat = Vec::new();
        for r in &rows {
            flat.extend_from_slice(r.as_slice());
        }
        let side = QuantSidecar::build(&flat, d);
        let sref = StoreRef { flat: &flat, d, quant: Some(&side) };
        let queries = uniform_sphere(q_count, d, 4242);
        let mut qb = QueryBlock::default();
        qb.reset(d);
        for q in &queries {
            qb.push(q.as_slice());
        }
        let kernel = QuantizedI8Kernel::new();
        let live: Vec<u32> = (0..q_count as u32).collect();
        let mut scratches: Vec<KernelScratch> =
            (0..q_count).map(|_| KernelScratch::new()).collect();
        let mut heaps: Vec<KnnHeap> = (0..q_count).map(|_| KnnHeap::new(k)).collect();
        let mut floors = vec![0.0f64; q_count];
        // 8 bucket-like visits of 16 rows, floors captured at each entry —
        // the multi-traversal leaf-visit shape.
        for b in 0..8usize {
            for (f, h) in floors.iter_mut().zip(&heaps) {
                *f = h.floor();
            }
            let sel = RowSel::Block { start: b * 16, n: 16 };
            kernel.scan_multi(&qb, &live, &floors, sref, sel, &mut scratches, &mut |j, pos, sim| {
                heaps[j].offer((b * 16 + pos) as u32, sim)
            });
        }
        for s in &scratches {
            assert_eq!(s.quant_builds(), 1, "one QuantQuery per slot per batch");
        }
        let scalar = ScalarKernel::default();
        for (h, q) in heaps.into_iter().zip(&queries) {
            let mut want = KnnHeap::new(k);
            scalar.scan_topk(
                q.as_slice(),
                sref,
                RowSel::Block { start: 0, n },
                &mut want,
                &mut KernelScratch::new(),
            );
            let (a, b) = (h.into_sorted(), want.into_sorted());
            assert_eq!(a.len(), b.len());
            for ((ia, sa), (ib, sb)) in a.iter().zip(&b) {
                assert_eq!(ia, ib);
                assert_eq!(sa.to_bits(), sb.to_bits());
            }
        }
    }

    #[test]
    fn non_finite_queries_fall_back_to_the_exact_path() {
        let rows = uniform_sphere(8, 6, 21);
        let mut flat = Vec::new();
        for r in &rows {
            flat.extend_from_slice(r.as_slice());
        }
        let side = QuantSidecar::build(&flat, 6);
        let q = [1.0f32, f32::NAN, 0.0, 0.0, 0.0, 0.0];
        assert!(QuantQuery::build(&q).is_none());
        // Through the backend: byte-identical heap to the scalar backend.
        let sref = StoreRef { flat: &flat, d: 6, quant: Some(&side) };
        let sel = RowSel::Block { start: 0, n: 8 };
        let quant = QuantizedI8Kernel::new();
        let scalar = ScalarKernel::default();
        let mut hq = KnnHeap::new(3);
        let mut hs = KnnHeap::new(3);
        quant.scan_topk(&q, sref, sel, &mut hq, &mut KernelScratch::new());
        scalar.scan_topk(&q, sref, sel, &mut hs, &mut KernelScratch::new());
        let (a, b) = (hq.into_sorted(), hs.into_sorted());
        assert_eq!(a.len(), b.len());
        for ((ia, sa), (ib, sb)) in a.iter().zip(&b) {
            assert_eq!(ia, ib);
            assert_eq!(sa.to_bits(), sb.to_bits());
        }
    }
}
