//! Zero-copy corpus storage: one contiguous SoA buffer under every index,
//! shard, and the PJRT runtime — scanned through pluggable kernel
//! backends.
//!
//! A [`CorpusStore`] owns the L2-normalized corpus as a single row-major
//! `f32` buffer behind an `Arc`. Everything downstream — index structures,
//! coordinator shards, the PJRT engine's input tiles — works on
//! [`CorpusView`] handles (a contiguous row range or an explicit id list)
//! that *alias* the buffer instead of cloning vectors. Scoring goes through
//! batch kernels ([`CorpusView::scan_topk`], [`CorpusView::scan_range`],
//! [`CorpusView::dot_batch`]) that dispatch to the store's
//! [`KernelBackend`] — scalar, SIMD, or i8-quantized (see the `kernels`
//! module and ADR-003). The backend is chosen per store
//! ([`CorpusStore::with_kernel`]) and inherited by every view, index,
//! shard, and ingest generation built over it.
//!
//! Numerical contract (ADR-003's two tiers): the *exact* backends (scalar,
//! SIMD) reduce each row with **exactly** the same operation order as
//! [`dot_slice`] (4-way unrolled partial sums, pairwise combine, sequential
//! tail, clamp to `[-1, 1]`), so the same `(query, row)` pair produces the
//! same `f64` bit pattern no matter which kernel — or which index — scored
//! it. The quantized backend pre-filters with a certified error bound and
//! re-ranks survivors through the exact kernel, so final scan results stay
//! byte-identical while fewer exact evaluations are spent. The exactness
//! tests rely on this to compare index results byte-for-byte against the
//! linear scan on tie-free corpora. (With *exact* f64 similarity ties —
//! e.g. duplicate rows — kNN results are still exact up to tie membership,
//! because an index may prune a subtree whose upper bound equals the
//! current floor; see the `index` module's exactness contract.)

pub mod kernels;

use std::ops::Range;
use std::sync::{Arc, OnceLock};

use crate::index::KnnHeap;
use crate::metrics::DenseVec;

pub use kernels::{
    backend_for, default_kernel, FilterMode, KernelBackend, KernelCounters, KernelKind,
    KernelScratch, MultiSimSink, QuantSidecar, QuantizedI8Kernel, QueryBlock, RowSel,
    ScalarKernel, SimdKernel, StoreRef,
};
pub use kernels::{QUANT_MAX_DIM, QUANT_MIN_ROWS};

/// Dot product of two equal-length slices with 4-way unrolled f64
/// accumulation, clamped to the cosine range `[-1, 1]`.
///
/// This is the canonical scalar kernel: [`DenseVec::dot`] and every blocked
/// kernel backend reduce rows in exactly this operation order (the SIMD
/// backend bit-identically; see `kernels`).
///
/// # Panics
/// Panics on dimension mismatch — silently truncating to the shorter length
/// would hide data corruption.
#[inline]
pub fn dot_slice(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(
        a.len(),
        b.len(),
        "dot_slice: dimension mismatch ({} vs {})",
        a.len(),
        b.len()
    );
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] as f64 * b[j] as f64;
        s1 += a[j + 1] as f64 * b[j + 1] as f64;
        s2 += a[j + 2] as f64 * b[j + 2] as f64;
        s3 += a[j + 3] as f64 * b[j + 3] as f64;
    }
    let mut sum = (s0 + s1) + (s2 + s3);
    for j in chunks * 4..n {
        sum += a[j] as f64 * b[j] as f64;
    }
    sum.clamp(-1.0, 1.0)
}

/// L2-normalize one row in place (zero rows stay all-zero), with the same
/// arithmetic as [`DenseVec::new`] so store-native generators produce
/// bit-identical rows to their `Vec<DenseVec>` counterparts.
pub fn normalize_row(row: &mut [f32]) {
    let norm: f64 = row.iter().map(|&v| v as f64 * v as f64).sum::<f64>().sqrt();
    if norm > 0.0 {
        let inv = (1.0 / norm) as f32;
        for v in row {
            *v *= inv;
        }
    }
}

struct StoreInner {
    /// Row-major `(n, d)` normalized corpus.
    data: Vec<f32>,
    n: usize,
    d: usize,
}

/// The shared, contiguous, L2-normalized corpus. Cloning is an `Arc` bump;
/// the float buffer is allocated exactly once per served corpus. Each store
/// carries a [`KernelBackend`] (default: [`default_kernel`], i.e. the
/// `SIMETRA_KERNEL` env var or scalar) that every view scan dispatches
/// through, plus the i8 sidecar when the backend is quantized.
#[derive(Clone)]
pub struct CorpusStore {
    inner: Arc<StoreInner>,
    kernel: Arc<dyn KernelBackend>,
    /// i8 sidecar cell (quantized backends only), shared by every clone of
    /// the store. Built exclusively at explicit warm points
    /// ([`CorpusStore::warm_quant_sidecar`]); scans only read it, so plain
    /// constructors stay O(1) and never-warmed stores scan exactly.
    quant: Arc<OnceLock<QuantSidecar>>,
    /// Lazily built per-request override backends (ADR-005), one slot per
    /// [`KernelKind`], shared by every clone so each override kind keeps
    /// one stable set of counters per served corpus.
    alt: Arc<[OnceLock<Arc<dyn KernelBackend>>; 3]>,
}

impl CorpusStore {
    fn attach(inner: Arc<StoreInner>, kernel: Arc<dyn KernelBackend>) -> Self {
        CorpusStore {
            inner,
            kernel,
            quant: Arc::new(OnceLock::new()),
            alt: Arc::new([OnceLock::new(), OnceLock::new(), OnceLock::new()]),
        }
    }

    /// Adopt a row-major buffer whose rows are already unit-norm (or
    /// intentionally raw). Zero-copy: the buffer becomes the store.
    ///
    /// # Panics
    /// Panics if `data.len()` is not a multiple of `d`, or if `d == 0` while
    /// `data` is non-empty.
    pub fn from_flat_normalized(data: Vec<f32>, d: usize) -> Self {
        Self::from_flat_normalized_with(data, d, backend_for(default_kernel()))
    }

    /// Like [`CorpusStore::from_flat_normalized`], adopting the buffer
    /// straight onto an existing backend instance (the ingest write path's
    /// constructor — no throwaway default backend is allocated).
    pub fn from_flat_normalized_with(
        data: Vec<f32>,
        d: usize,
        kernel: Arc<dyn KernelBackend>,
    ) -> Self {
        if d == 0 {
            assert!(data.is_empty(), "d=0 store must be empty");
            return Self::attach(Arc::new(StoreInner { data, n: 0, d: 0 }), kernel);
        }
        assert_eq!(data.len() % d, 0, "flat corpus length {} not a multiple of d={d}", data.len());
        let n = data.len() / d;
        Self::attach(Arc::new(StoreInner { data, n, d }), kernel)
    }

    /// Adopt a row-major buffer of raw rows, L2-normalizing each in place.
    pub fn from_flat(mut data: Vec<f32>, d: usize) -> Self {
        if d > 0 {
            for row in data.chunks_mut(d) {
                normalize_row(row);
            }
        }
        Self::from_flat_normalized(data, d)
    }

    /// Pack already-normalized vectors into one contiguous buffer (the one
    /// copy at ingest; everything downstream aliases it).
    ///
    /// # Panics
    /// Panics if the rows do not all share one dimension.
    pub fn from_rows(rows: Vec<DenseVec>) -> Self {
        let d = rows.first().map(|v| v.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(rows.len() * d);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), d, "row {i} has dimension {} != {d}", row.len());
            data.extend_from_slice(row.as_slice());
        }
        Self::from_flat_normalized(data, d)
    }

    /// The same store (same buffer, `Arc` bump) scanned through a fresh
    /// backend of the given kind. Quantized kinds build the i8 sidecar
    /// here — an explicit configuration moment, off the query path.
    pub fn with_kernel(self, kind: KernelKind) -> Self {
        let store = Self::attach(self.inner, backend_for(kind));
        store.warm_quant_sidecar();
        store
    }

    /// The same store scanned through a *shared* backend instance — how
    /// the ingest layer gives every generation one set of counters.
    /// Quantized sidecars build here, like [`CorpusStore::with_kernel`].
    pub fn with_backend(self, backend: Arc<dyn KernelBackend>) -> Self {
        let store = Self::attach(self.inner, backend);
        store.warm_quant_sidecar();
        store
    }

    /// The active kernel backend.
    pub fn kernel(&self) -> &Arc<dyn KernelBackend> {
        &self.kernel
    }

    pub fn kernel_kind(&self) -> KernelKind {
        self.kernel.kind()
    }

    /// The backend a per-request kernel override resolves to (ADR-005):
    /// the primary backend when `kind` matches it, otherwise a lazily
    /// built per-store instance of `kind` (with its own counters). Exact
    /// kinds always scan correctly; an i8 override on a store without a
    /// sidecar degrades to exact scans inside the quantized backend — the
    /// coordinator rejects that combination up front with
    /// `KernelUnavailable` so it never reaches a scan in serving.
    pub fn kernel_for(&self, kind: KernelKind) -> Arc<dyn KernelBackend> {
        if kind == self.kernel.kind() {
            return self.kernel.clone();
        }
        let slot = match kind {
            KernelKind::Scalar => &self.alt[0],
            KernelKind::Simd => &self.alt[1],
            KernelKind::QuantizedI8 => &self.alt[2],
        };
        slot.get_or_init(|| backend_for(kind)).clone()
    }

    /// Build the i8 sidecar now. A no-op (returning `None`) unless the
    /// backend is quantized and the store has at least [`QUANT_MIN_ROWS`]
    /// rows — below that the pre-filter cannot pay for itself. Runs at
    /// explicit configuration moments only ([`CorpusStore::with_kernel`] /
    /// [`CorpusStore::with_backend`], `Generation::build` on the sealer
    /// thread, `Coordinator::new` at startup); scans read the sidecar
    /// through [`CorpusStore::quant_sidecar`] and never build one, so a
    /// store that was never warmed — the copy-on-write ingest memtable —
    /// always scans exactly, whatever its size.
    pub fn warm_quant_sidecar(&self) -> Option<&QuantSidecar> {
        let quantized = self.kernel.kind() == KernelKind::QuantizedI8;
        // Refuse oversized dims as well as tiny stores: an i8 backend that
        // cannot quantize simply scans exactly — never a panic. Config
        // layers reject the oversized case with a clean error
        // (KernelKind::validate_dim); this guard covers env-default paths.
        if !quantized || self.len() < QUANT_MIN_ROWS || self.dim() >= QUANT_MAX_DIM {
            return None;
        }
        let inner = &self.inner;
        Some(self.quant.get_or_init(|| QuantSidecar::build(&inner.data, inner.d)))
    }

    /// The i8 sidecar, if one was built (read-only; see
    /// [`CorpusStore::warm_quant_sidecar`]).
    pub fn quant_sidecar(&self) -> Option<&QuantSidecar> {
        if self.kernel.kind() != KernelKind::QuantizedI8 {
            return None;
        }
        self.quant.get()
    }

    /// Number of corpus rows.
    pub fn len(&self) -> usize {
        self.inner.n
    }

    pub fn is_empty(&self) -> bool {
        self.inner.n == 0
    }

    /// Vector-space dimension (0 for an empty store).
    pub fn dim(&self) -> usize {
        self.inner.d
    }

    /// The whole row-major buffer — directly usable as a PJRT input slab.
    pub fn flat(&self) -> &[f32] {
        &self.inner.data
    }

    /// Row `i` as a borrowed slice (zero-copy).
    pub fn row(&self, i: usize) -> &[f32] {
        let d = self.inner.d;
        &self.inner.data[i * d..(i + 1) * d]
    }

    /// Row `i` as a typed zero-copy handle.
    pub fn vec_ref(&self, i: usize) -> VecRef<'_> {
        VecRef { data: self.row(i) }
    }

    /// Owned copy of row `i` (query extraction, diagnostics).
    pub fn vec(&self, i: usize) -> DenseVec {
        DenseVec::from_normalized(self.row(i).to_vec())
    }

    /// View over every row.
    pub fn view(&self) -> CorpusView {
        self.slice(0..self.len())
    }

    /// View over a contiguous row range (aliases the buffer; the basis of
    /// shard partitioning).
    pub fn slice(&self, rows: Range<usize>) -> CorpusView {
        assert!(rows.start <= rows.end && rows.end <= self.len(), "slice {rows:?} out of bounds");
        CorpusView { store: self.clone(), sel: Selection::Rows(rows.start, rows.end) }
    }

    /// View over an explicit list of row ids (aliases the buffer).
    ///
    /// # Panics
    /// Panics if any id is out of range.
    pub fn select(&self, ids: Vec<u32>) -> CorpusView {
        for &id in &ids {
            assert!((id as usize) < self.len(), "id {id} out of range 0..{}", self.len());
        }
        CorpusView { store: self.clone(), sel: Selection::Ids(Arc::new(IdSelection::new(ids))) }
    }
}

impl From<Vec<DenseVec>> for CorpusStore {
    fn from(rows: Vec<DenseVec>) -> Self {
        CorpusStore::from_rows(rows)
    }
}

/// A borrowed, normalized corpus row.
#[derive(Clone, Copy)]
pub struct VecRef<'a> {
    data: &'a [f32],
}

impl<'a> VecRef<'a> {
    pub fn as_slice(&self) -> &'a [f32] {
        self.data
    }

    pub fn dim(&self) -> usize {
        self.data.len()
    }

    /// Cosine similarity to another row (both pre-normalized).
    pub fn dot(&self, other: VecRef<'_>) -> f64 {
        dot_slice(self.data, other.data)
    }

    pub fn to_owned(self) -> DenseVec {
        DenseVec::from_normalized(self.data.to_vec())
    }
}

/// An explicit id-list selection, with a lazily gathered contiguous copy
/// of its rows. The cache is shared by every clone of the view, so
/// repeated [`CorpusView::contiguous_or_gather`] calls (engine tiles,
/// bucket slabs) gather at most once.
struct IdSelection {
    ids: Vec<u32>,
    gathered: OnceLock<Vec<f32>>,
}

impl IdSelection {
    fn new(ids: Vec<u32>) -> Self {
        IdSelection { ids, gathered: OnceLock::new() }
    }
}

#[derive(Clone)]
enum Selection {
    /// Contiguous store rows `[start, end)`; local id `i` is row `start + i`.
    Rows(usize, usize),
    /// Explicit store rows; local id `i` is row `ids[i]`.
    Ids(Arc<IdSelection>),
}

/// A zero-copy window onto a [`CorpusStore`]: the unit indexes build from,
/// shards own, and the PJRT runtime feeds from. Local ids `0..len` map to
/// store rows through the selection. Every scan dispatches to the store's
/// [`KernelBackend`].
#[derive(Clone)]
pub struct CorpusView {
    store: CorpusStore,
    sel: Selection,
}

impl CorpusView {
    pub fn len(&self) -> usize {
        match &self.sel {
            Selection::Rows(lo, hi) => hi - lo,
            Selection::Ids(sel) => sel.ids.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dim(&self) -> usize {
        self.store.dim()
    }

    pub fn store(&self) -> &CorpusStore {
        &self.store
    }

    /// Store row index backing local id `local`.
    pub fn store_row(&self, local: u32) -> usize {
        match &self.sel {
            Selection::Rows(lo, hi) => {
                let r = *lo + local as usize;
                assert!(r < *hi, "local id {local} out of view of {} rows", *hi - *lo);
                r
            }
            Selection::Ids(sel) => sel.ids[local as usize] as usize,
        }
    }

    /// Local row `local` as a borrowed slice (zero-copy).
    pub fn row(&self, local: u32) -> &[f32] {
        self.store.row(self.store_row(local))
    }

    pub fn vec_ref(&self, local: u32) -> VecRef<'_> {
        VecRef { data: self.row(local) }
    }

    /// Owned copy of local row `local`.
    pub fn vec(&self, local: u32) -> DenseVec {
        DenseVec::from_normalized(self.row(local).to_vec())
    }

    /// The view's rows as one contiguous row-major slab, if the selection is
    /// a row range — the zero-copy path into the PJRT input buffer.
    pub fn as_contiguous(&self) -> Option<&[f32]> {
        match &self.sel {
            Selection::Rows(lo, hi) => {
                let d = self.dim();
                Some(&self.store.flat()[lo * d..hi * d])
            }
            Selection::Ids(_) => None,
        }
    }

    /// Contiguous slab of the view's rows. Row-range views borrow the
    /// store buffer; id-list views gather **once** into a cache shared by
    /// all clones of the view (repeat calls are zero-copy too), so per-query
    /// consumers stop re-allocating.
    pub fn contiguous_or_gather(&self) -> &[f32] {
        match &self.sel {
            Selection::Rows(lo, hi) => {
                let d = self.dim();
                &self.store.flat()[lo * d..hi * d]
            }
            Selection::Ids(sel) => sel.gathered.get_or_init(|| {
                let d = self.dim();
                let mut out = Vec::with_capacity(sel.ids.len() * d);
                for &id in &sel.ids {
                    out.extend_from_slice(self.store.row(id as usize));
                }
                out
            }),
        }
    }

    /// Sub-view over local rows `[lo, hi)` (engine tiling).
    pub fn slice_rows(&self, lo: usize, hi: usize) -> CorpusView {
        assert!(lo <= hi && hi <= self.len(), "slice_rows {lo}..{hi} out of {}", self.len());
        let sel = match &self.sel {
            Selection::Rows(start, _) => Selection::Rows(start + lo, start + hi),
            Selection::Ids(sel) => {
                Selection::Ids(Arc::new(IdSelection::new(sel.ids[lo..hi].to_vec())))
            }
        };
        CorpusView { store: self.store.clone(), sel }
    }

    fn store_ref(&self) -> StoreRef<'_> {
        let store = &self.store;
        StoreRef { flat: store.flat(), d: store.dim(), quant: store.quant_sidecar() }
    }

    /// The backend this scan dispatches through: the scratch's per-request
    /// override when armed (ADR-005), else the store's primary backend.
    fn scan_kernel(&self, scratch: &KernelScratch) -> Arc<dyn KernelBackend> {
        match scratch.kernel_override() {
            Some(kind) => self.store.kernel_for(kind),
            None => self.store.kernel.clone(),
        }
    }

    fn check_query(&self, q: &[f32]) {
        assert_eq!(
            q.len(),
            self.dim(),
            "query dimension {} != corpus dimension {}",
            q.len(),
            self.dim()
        );
    }

    fn check_locals(&self, locals: &[u32]) {
        let n = self.len();
        for &l in locals {
            assert!((l as usize) < n, "local id {l} out of view of {n} rows");
        }
    }

    /// Resolve `locals` into a backend gather: `(mapped_rows, base)` such
    /// that store row `pos` = `base + rows[pos]`, where `rows` is `locals`
    /// itself for row-range views (`mapped_rows = None`) or the id-mapped
    /// copy for id-list views.
    fn resolve_locals(&self, locals: &[u32]) -> (Option<Vec<u32>>, usize) {
        match &self.sel {
            Selection::Rows(lo, _) => {
                self.check_locals(locals);
                (None, *lo)
            }
            Selection::Ids(sel) => {
                let rows = locals.iter().map(|&l| sel.ids[l as usize]).collect();
                (Some(rows), 0)
            }
        }
    }

    /// Invoke `f(local_id, sim)` for every row of the view, through the
    /// backend's **exact** block/gather kernels (always bit-identical to
    /// [`dot_slice`], whatever the backend kind).
    pub fn for_each_sim(&self, q: &[f32], mut f: impl FnMut(u32, f64)) {
        let d = self.dim();
        self.check_query(q);
        if d == 0 {
            for i in 0..self.len() as u32 {
                f(i, 0.0);
            }
            return;
        }
        let sink = &mut |pos: usize, s: f64| f(pos as u32, s);
        match &self.sel {
            Selection::Rows(lo, hi) => {
                let (lo, hi) = (*lo, *hi);
                let block = &self.store.flat()[lo * d..hi * d];
                self.store.kernel.sim_block(q, block, d, hi - lo, sink);
            }
            Selection::Ids(sel) => {
                self.store.kernel.sim_gather(q, self.store.flat(), d, &sel.ids, 0, sink);
            }
        }
    }

    /// Blocked batch dot: **exact** similarities of `q` to the given local
    /// ids, replacing `out`'s contents in matching order.
    pub fn dot_batch(&self, q: &[f32], locals: &[u32], out: &mut Vec<f64>) {
        self.check_query(q);
        out.clear();
        out.reserve(locals.len());
        let d = self.dim();
        let flat = self.store.flat();
        let (mapped, base) = self.resolve_locals(locals);
        let rows = mapped.as_deref().unwrap_or(locals);
        let sink = &mut |_: usize, s: f64| out.push(s);
        self.store.kernel.sim_gather(q, flat, d, rows, base, sink);
    }

    /// Full-view top-k scan through the backend: offer rows to `heap`
    /// (quantized backends pre-filter and re-rank, exact backends offer
    /// every row). Returns the number of exact similarity evaluations.
    ///
    /// Self-contained form: builds a throwaway [`KernelScratch`], so a
    /// quantized backend re-quantizes the query here. Steady-state callers
    /// thread a context's scratch through [`CorpusView::scan_topk_with`].
    pub fn scan_topk(&self, q: &[f32], heap: &mut KnnHeap) -> u64 {
        self.scan_topk_with(q, heap, &mut KernelScratch::new())
    }

    /// [`CorpusView::scan_topk`] with a borrowed per-query scratch: the
    /// quantized query is built at most once per query however many scans
    /// share the scratch (ADR-004).
    pub fn scan_topk_with(
        &self,
        q: &[f32],
        heap: &mut KnnHeap,
        scratch: &mut KernelScratch,
    ) -> u64 {
        self.check_query(q);
        if self.is_empty() {
            return 0;
        }
        let s = self.store_ref();
        let kernel = self.scan_kernel(scratch);
        match &self.sel {
            Selection::Rows(lo, hi) => {
                let sel = RowSel::Block { start: *lo, n: *hi - *lo };
                kernel.scan_topk(q, s, sel, heap, scratch)
            }
            Selection::Ids(sel) => {
                let gather = RowSel::Gather { rows: &sel.ids, base: 0, report: None };
                kernel.scan_topk(q, s, gather, heap, scratch)
            }
        }
    }

    /// Full-view range scan through the backend: push every `(local, sim)`
    /// with `sim >= tau`, in ascending local order. Returns exact evals.
    /// (Throwaway scratch; see [`CorpusView::scan_topk`].)
    pub fn scan_range(&self, q: &[f32], tau: f64, out: &mut Vec<(u32, f64)>) -> u64 {
        self.scan_range_with(q, tau, out, &mut KernelScratch::new())
    }

    /// [`CorpusView::scan_range`] with a borrowed per-query scratch.
    pub fn scan_range_with(
        &self,
        q: &[f32],
        tau: f64,
        out: &mut Vec<(u32, f64)>,
        scratch: &mut KernelScratch,
    ) -> u64 {
        self.check_query(q);
        if self.is_empty() {
            return 0;
        }
        let s = self.store_ref();
        let kernel = self.scan_kernel(scratch);
        match &self.sel {
            Selection::Rows(lo, hi) => {
                let sel = RowSel::Block { start: *lo, n: *hi - *lo };
                kernel.scan_range(q, s, sel, tau, out, scratch)
            }
            Selection::Ids(sel) => {
                let gather = RowSel::Gather { rows: &sel.ids, base: 0, report: None };
                kernel.scan_range(q, s, gather, tau, out, scratch)
            }
        }
    }

    /// Blocked id-list top-k scan (leaf buckets). Returns exact evals.
    /// (Throwaway scratch; see [`CorpusView::scan_topk`].)
    pub fn scan_ids_topk(&self, q: &[f32], locals: &[u32], heap: &mut KnnHeap) -> u64 {
        self.scan_ids_topk_with(q, locals, heap, &mut KernelScratch::new())
    }

    /// [`CorpusView::scan_ids_topk`] with a borrowed per-query scratch —
    /// the leaf-bucket hot path of every tree index: with a reused scratch,
    /// a quantized backend quantizes the query once per query, not once
    /// per bucket.
    pub fn scan_ids_topk_with(
        &self,
        q: &[f32],
        locals: &[u32],
        heap: &mut KnnHeap,
        scratch: &mut KernelScratch,
    ) -> u64 {
        self.check_query(q);
        if locals.is_empty() {
            return 0;
        }
        let s = self.store_ref();
        let kernel = self.scan_kernel(scratch);
        let (mapped, base) = self.resolve_locals(locals);
        let rows = mapped.as_deref().unwrap_or(locals);
        let gather = RowSel::Gather { rows, base, report: Some(locals) };
        kernel.scan_topk(q, s, gather, heap, scratch)
    }

    /// Blocked id-list range scan (leaf buckets). Returns exact evals.
    /// (Throwaway scratch; see [`CorpusView::scan_topk`].)
    pub fn scan_ids_range(
        &self,
        q: &[f32],
        locals: &[u32],
        tau: f64,
        out: &mut Vec<(u32, f64)>,
    ) -> u64 {
        self.scan_ids_range_with(q, locals, tau, out, &mut KernelScratch::new())
    }

    /// [`CorpusView::scan_ids_range`] with a borrowed per-query scratch.
    pub fn scan_ids_range_with(
        &self,
        q: &[f32],
        locals: &[u32],
        tau: f64,
        out: &mut Vec<(u32, f64)>,
        scratch: &mut KernelScratch,
    ) -> u64 {
        self.check_query(q);
        if locals.is_empty() {
            return 0;
        }
        let s = self.store_ref();
        let kernel = self.scan_kernel(scratch);
        let (mapped, base) = self.resolve_locals(locals);
        let rows = mapped.as_deref().unwrap_or(locals);
        let gather = RowSel::Gather { rows, base, report: Some(locals) };
        kernel.scan_range(q, s, gather, tau, out, scratch)
    }

    fn check_query_block(&self, qb: &QueryBlock) {
        assert_eq!(
            qb.dim(),
            self.dim(),
            "query block dimension {} != corpus dimension {}",
            qb.dim(),
            self.dim()
        );
    }

    /// Multi-query full-view scan (the batched-traversal leaf path,
    /// ADR-006): every live query slot scores every view row through one
    /// [`KernelBackend::scan_multi`] call. `sink(slot, pos, sim)` receives
    /// selection positions `0..len`; the caller maps positions to ids.
    /// Exact backends invoke the sink for every `(live slot, row)` pair;
    /// the quantized backend pre-filters each slot against `floors[slot]`
    /// with certified upper bounds and re-ranks survivors exactly, so
    /// every delivered sim is bit-identical to [`dot_slice`]. Returns the
    /// number of sink invocations (exact evaluations delivered).
    ///
    /// The batch path serves *plain* plans only, so this always dispatches
    /// the store's primary backend — no per-request override resolution.
    pub fn scan_all_multi_with(
        &self,
        qb: &QueryBlock,
        live: &[u32],
        floors: &[f64],
        scratches: &mut [KernelScratch],
        sink: MultiSimSink<'_>,
    ) -> u64 {
        self.check_query_block(qb);
        if self.is_empty() || live.is_empty() {
            return 0;
        }
        let s = self.store_ref();
        match &self.sel {
            Selection::Rows(lo, hi) => {
                let sel = RowSel::Block { start: *lo, n: *hi - *lo };
                self.store.kernel.scan_multi(qb, live, floors, s, sel, scratches, sink)
            }
            Selection::Ids(sel) => {
                let gather = RowSel::Gather { rows: &sel.ids, base: 0, report: None };
                self.store.kernel.scan_multi(qb, live, floors, s, gather, scratches, sink)
            }
        }
    }

    /// Multi-query id-list scan (the batched leaf-bucket hot path,
    /// ADR-006): like [`CorpusView::scan_all_multi_with`] over an explicit
    /// local-id list. `sink(slot, pos, sim)` receives positions into
    /// `locals`; the caller maps `pos` back through `locals[pos]`.
    pub fn scan_ids_multi_with(
        &self,
        qb: &QueryBlock,
        locals: &[u32],
        live: &[u32],
        floors: &[f64],
        scratches: &mut [KernelScratch],
        sink: MultiSimSink<'_>,
    ) -> u64 {
        self.check_query_block(qb);
        if locals.is_empty() || live.is_empty() {
            return 0;
        }
        let s = self.store_ref();
        let (mapped, base) = self.resolve_locals(locals);
        let rows = mapped.as_deref().unwrap_or(locals);
        let gather = RowSel::Gather { rows, base, report: None };
        self.store.kernel.scan_multi(qb, live, floors, s, gather, scratches, sink)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::uniform_sphere;

    fn store_of(n: usize, d: usize, seed: u64) -> (CorpusStore, Vec<DenseVec>) {
        let rows = uniform_sphere(n, d, seed);
        (CorpusStore::from_rows(rows.clone()), rows)
    }

    #[test]
    fn from_rows_is_contiguous_and_aliased_by_views() {
        let (store, rows) = store_of(10, 6, 1);
        assert_eq!(store.len(), 10);
        assert_eq!(store.dim(), 6);
        for (i, r) in rows.iter().enumerate() {
            assert_eq!(store.row(i), r.as_slice());
        }
        let v = store.slice(3..7);
        assert_eq!(v.len(), 4);
        // Views alias the buffer: same pointers, no copies.
        assert!(std::ptr::eq(v.row(0), &store.flat()[3 * 6..4 * 6]));
        assert!(std::ptr::eq(
            v.as_contiguous().unwrap(),
            &store.flat()[3 * 6..7 * 6]
        ));
    }

    #[test]
    #[should_panic(expected = "dimension")]
    fn from_rows_rejects_ragged_rows() {
        CorpusStore::from_rows(vec![
            DenseVec::new(vec![1.0, 0.0]),
            DenseVec::new(vec![1.0, 0.0, 0.0]),
        ]);
    }

    #[test]
    #[should_panic(expected = "dimension mismatch")]
    fn dot_slice_rejects_dim_mismatch() {
        dot_slice(&[1.0, 0.0], &[1.0, 0.0, 0.0]);
    }

    #[test]
    fn blocked_kernels_match_dot_slice_bitwise() {
        // Sizes straddling the block and pair boundaries, odd dims for tails.
        for (n, d) in [(1usize, 3usize), (2, 4), (7, 5), (8, 8), (9, 13), (33, 17)] {
            let (store, rows) = store_of(n, d, 42 + n as u64);
            let q = uniform_sphere(1, d, 999).pop().unwrap();
            let view = store.view();
            let mut got = Vec::new();
            view.for_each_sim(q.as_slice(), |local, s| got.push((local, s)));
            assert_eq!(got.len(), n);
            for (local, s) in got {
                let want = dot_slice(q.as_slice(), rows[local as usize].as_slice());
                assert!(
                    s == want,
                    "row {local}: blocked {s:?} != scalar {want:?} (n={n} d={d})"
                );
            }
        }
    }

    #[test]
    fn id_selection_and_dot_batch_match_per_row() {
        let (store, rows) = store_of(20, 9, 7);
        let q = uniform_sphere(1, 9, 1000).pop().unwrap();
        let picked = vec![3u32, 17, 0, 11, 5];
        let view = store.select(picked.clone());
        assert!(view.as_contiguous().is_none());
        let gathered = view.contiguous_or_gather();
        assert_eq!(gathered.len(), picked.len() * 9);

        // Full-view scan over the id selection.
        let mut sims = Vec::new();
        view.for_each_sim(q.as_slice(), |local, s| sims.push((local, s)));
        for (local, s) in sims {
            let want = dot_slice(q.as_slice(), rows[picked[local as usize] as usize].as_slice());
            assert!(s == want);
        }

        // dot_batch over locals of a row-range view.
        let range_view = store.slice(2..18);
        let locals = vec![0u32, 15, 7, 3, 3, 8];
        let mut out = Vec::new();
        range_view.dot_batch(q.as_slice(), &locals, &mut out);
        assert_eq!(out.len(), locals.len());
        for (pos, &s) in out.iter().enumerate() {
            let want =
                dot_slice(q.as_slice(), rows[2 + locals[pos] as usize].as_slice());
            assert!(s == want);
        }
    }

    #[test]
    fn id_list_gather_is_cached_across_calls_and_clones() {
        let (store, _) = store_of(30, 5, 21);
        let view = store.select(vec![7, 2, 19, 4]);
        let first = view.contiguous_or_gather();
        let second = view.contiguous_or_gather();
        // The second scan performs zero gathers: same allocation.
        assert!(std::ptr::eq(first, second));
        let clone = view.clone();
        assert!(std::ptr::eq(first, clone.contiguous_or_gather()));
        // Sub-views get their own (fresh) cache.
        let sub = view.slice_rows(1, 3);
        assert_eq!(sub.contiguous_or_gather().len(), 2 * 5);
    }

    #[test]
    fn scan_kernels_filter_and_rank() {
        let (store, rows) = store_of(50, 8, 3);
        let view = store.view();
        let q = rows[4].clone();
        let mut out = Vec::new();
        let evals = view.scan_range(q.as_slice(), 0.5, &mut out);
        assert_eq!(evals, 50);
        assert!(out.iter().any(|&(id, _)| id == 4));
        assert!(out.iter().all(|&(_, s)| s >= 0.5));

        let mut heap = KnnHeap::new(5);
        view.scan_topk(q.as_slice(), &mut heap);
        let top = heap.into_sorted();
        assert_eq!(top[0].0, 4);
        assert!((top[0].1 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn with_kernel_swaps_backend_without_copying_the_buffer() {
        let (store, _) = store_of(12, 6, 17);
        let simd = store.clone().with_kernel(KernelKind::Simd);
        assert!(std::ptr::eq(store.flat(), simd.flat()));
        assert_eq!(simd.kernel_kind(), KernelKind::Simd);
        assert!(simd.quant_sidecar().is_none());
        // Small stores scan exactly even under i8 (no sidecar) — the
        // memtable-rebuild guarantee; large stores get one, lazily.
        let quant = store.clone().with_kernel(KernelKind::QuantizedI8);
        assert!(std::ptr::eq(store.flat(), quant.flat()));
        assert!(quant.quant_sidecar().is_none());
        let (big, _) = store_of(QUANT_MIN_ROWS, 4, 18);
        let big = big.with_kernel(KernelKind::QuantizedI8);
        assert!(big.quant_sidecar().is_some());
        // The sidecar is cached: same pointer on the second call.
        let a = big.quant_sidecar().unwrap() as *const QuantSidecar;
        let b = big.quant_sidecar().unwrap() as *const QuantSidecar;
        assert_eq!(a, b);
    }

    #[test]
    fn store_clone_shares_the_buffer() {
        let (store, _) = store_of(5, 4, 11);
        let clone = store.clone();
        assert!(std::ptr::eq(store.flat(), clone.flat()));
    }

    #[test]
    fn empty_store_is_usable() {
        let store = CorpusStore::from_flat_normalized(Vec::new(), 0);
        assert!(store.is_empty());
        let view = store.view();
        assert_eq!(view.len(), 0);
        assert!(view.as_contiguous().unwrap().is_empty());
    }
}
