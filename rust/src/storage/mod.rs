//! Zero-copy corpus storage: one contiguous SoA buffer under every index,
//! shard, and the PJRT runtime.
//!
//! A [`CorpusStore`] owns the L2-normalized corpus as a single row-major
//! `f32` buffer behind an `Arc`. Everything downstream — index structures,
//! coordinator shards, the PJRT engine's input tiles — works on
//! [`CorpusView`] handles (a contiguous row range or an explicit id list)
//! that *alias* the buffer instead of cloning vectors. Scoring goes through
//! batch kernels ([`CorpusView::scan_topk`], [`CorpusView::scan_range`],
//! [`CorpusView::dot_batch`]) built on a paired row kernel (`dot2`) that
//! streams the query once per two rows with f64 accumulation — wider
//! (SIMD/8-row) kernels can slot in behind the same API later.
//!
//! Numerical contract: every kernel reduces each row with **exactly** the
//! same operation order as [`dot_slice`] (4-way unrolled partial sums,
//! pairwise combine, sequential tail, clamp to `[-1, 1]`), so the same
//! `(query, row)` pair produces the same `f64` bit pattern no matter which
//! kernel — or which index — scored it. The exactness tests rely on this to
//! compare index results byte-for-byte against the linear scan on
//! tie-free corpora. (With *exact* f64 similarity ties — e.g. duplicate
//! rows — kNN results are still exact up to tie membership, because an
//! index may prune a subtree whose upper bound equals the current floor;
//! see the `index` module's exactness contract.)

use std::borrow::Cow;
use std::ops::Range;
use std::sync::Arc;

use crate::index::KnnHeap;
use crate::metrics::DenseVec;

/// Dot product of two equal-length slices with 4-way unrolled f64
/// accumulation, clamped to the cosine range `[-1, 1]`.
///
/// This is the canonical scalar kernel: [`DenseVec::dot`] and every blocked
/// kernel in this module reduce rows in exactly this operation order.
///
/// # Panics
/// Panics on dimension mismatch — silently truncating to the shorter length
/// would hide data corruption.
#[inline]
pub fn dot_slice(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(
        a.len(),
        b.len(),
        "dot_slice: dimension mismatch ({} vs {})",
        a.len(),
        b.len()
    );
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] as f64 * b[j] as f64;
        s1 += a[j + 1] as f64 * b[j + 1] as f64;
        s2 += a[j + 2] as f64 * b[j + 2] as f64;
        s3 += a[j + 3] as f64 * b[j + 3] as f64;
    }
    let mut sum = (s0 + s1) + (s2 + s3);
    for j in chunks * 4..n {
        sum += a[j] as f64 * b[j] as f64;
    }
    sum.clamp(-1.0, 1.0)
}

/// Two rows against one query in a single pass: the query stream is loaded
/// once and feeds two independent 4-way accumulator sets, replicating
/// [`dot_slice`]'s reduction order bit-for-bit for each row.
#[inline]
fn dot2(q: &[f32], r0: &[f32], r1: &[f32]) -> (f64, f64) {
    let n = q.len();
    debug_assert_eq!(r0.len(), n);
    debug_assert_eq!(r1.len(), n);
    let (r0, r1) = (&r0[..n], &r1[..n]);
    let chunks = n / 4;
    let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    let (mut b0, mut b1, mut b2, mut b3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    for i in 0..chunks {
        let j = i * 4;
        let (q0, q1, q2, q3) =
            (q[j] as f64, q[j + 1] as f64, q[j + 2] as f64, q[j + 3] as f64);
        a0 += q0 * r0[j] as f64;
        a1 += q1 * r0[j + 1] as f64;
        a2 += q2 * r0[j + 2] as f64;
        a3 += q3 * r0[j + 3] as f64;
        b0 += q0 * r1[j] as f64;
        b1 += q1 * r1[j + 1] as f64;
        b2 += q2 * r1[j + 2] as f64;
        b3 += q3 * r1[j + 3] as f64;
    }
    let mut sa = (a0 + a1) + (a2 + a3);
    let mut sb = (b0 + b1) + (b2 + b3);
    for j in chunks * 4..n {
        sa += q[j] as f64 * r0[j] as f64;
        sb += q[j] as f64 * r1[j] as f64;
    }
    (sa.clamp(-1.0, 1.0), sb.clamp(-1.0, 1.0))
}

/// L2-normalize one row in place (zero rows stay all-zero), with the same
/// arithmetic as [`DenseVec::new`] so store-native generators produce
/// bit-identical rows to their `Vec<DenseVec>` counterparts.
pub fn normalize_row(row: &mut [f32]) {
    let norm: f64 = row.iter().map(|&v| v as f64 * v as f64).sum::<f64>().sqrt();
    if norm > 0.0 {
        let inv = (1.0 / norm) as f32;
        for v in row {
            *v *= inv;
        }
    }
}

struct StoreInner {
    /// Row-major `(n, d)` normalized corpus.
    data: Vec<f32>,
    n: usize,
    d: usize,
}

/// The shared, contiguous, L2-normalized corpus. Cloning is an `Arc` bump;
/// the float buffer is allocated exactly once per served corpus.
#[derive(Clone)]
pub struct CorpusStore {
    inner: Arc<StoreInner>,
}

impl CorpusStore {
    /// Adopt a row-major buffer whose rows are already unit-norm (or
    /// intentionally raw). Zero-copy: the buffer becomes the store.
    ///
    /// # Panics
    /// Panics if `data.len()` is not a multiple of `d`, or if `d == 0` while
    /// `data` is non-empty.
    pub fn from_flat_normalized(data: Vec<f32>, d: usize) -> Self {
        if d == 0 {
            assert!(data.is_empty(), "d=0 store must be empty");
            return CorpusStore { inner: Arc::new(StoreInner { data, n: 0, d: 0 }) };
        }
        assert_eq!(data.len() % d, 0, "flat corpus length {} not a multiple of d={d}", data.len());
        let n = data.len() / d;
        CorpusStore { inner: Arc::new(StoreInner { data, n, d }) }
    }

    /// Adopt a row-major buffer of raw rows, L2-normalizing each in place.
    pub fn from_flat(mut data: Vec<f32>, d: usize) -> Self {
        if d > 0 {
            for row in data.chunks_mut(d) {
                normalize_row(row);
            }
        }
        Self::from_flat_normalized(data, d)
    }

    /// Pack already-normalized vectors into one contiguous buffer (the one
    /// copy at ingest; everything downstream aliases it).
    ///
    /// # Panics
    /// Panics if the rows do not all share one dimension.
    pub fn from_rows(rows: Vec<DenseVec>) -> Self {
        let d = rows.first().map(|v| v.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(rows.len() * d);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), d, "row {i} has dimension {} != {d}", row.len());
            data.extend_from_slice(row.as_slice());
        }
        Self::from_flat_normalized(data, d)
    }

    /// Number of corpus rows.
    pub fn len(&self) -> usize {
        self.inner.n
    }

    pub fn is_empty(&self) -> bool {
        self.inner.n == 0
    }

    /// Vector-space dimension (0 for an empty store).
    pub fn dim(&self) -> usize {
        self.inner.d
    }

    /// The whole row-major buffer — directly usable as a PJRT input slab.
    pub fn flat(&self) -> &[f32] {
        &self.inner.data
    }

    /// Row `i` as a borrowed slice (zero-copy).
    pub fn row(&self, i: usize) -> &[f32] {
        let d = self.inner.d;
        &self.inner.data[i * d..(i + 1) * d]
    }

    /// Row `i` as a typed zero-copy handle.
    pub fn vec_ref(&self, i: usize) -> VecRef<'_> {
        VecRef { data: self.row(i) }
    }

    /// Owned copy of row `i` (query extraction, diagnostics).
    pub fn vec(&self, i: usize) -> DenseVec {
        DenseVec::from_normalized(self.row(i).to_vec())
    }

    /// View over every row.
    pub fn view(&self) -> CorpusView {
        self.slice(0..self.len())
    }

    /// View over a contiguous row range (aliases the buffer; the basis of
    /// shard partitioning).
    pub fn slice(&self, rows: Range<usize>) -> CorpusView {
        assert!(rows.start <= rows.end && rows.end <= self.len(), "slice {rows:?} out of bounds");
        CorpusView { store: self.clone(), sel: Selection::Rows(rows.start, rows.end) }
    }

    /// View over an explicit list of row ids (aliases the buffer).
    ///
    /// # Panics
    /// Panics if any id is out of range.
    pub fn select(&self, ids: Vec<u32>) -> CorpusView {
        for &id in &ids {
            assert!((id as usize) < self.len(), "id {id} out of range 0..{}", self.len());
        }
        CorpusView { store: self.clone(), sel: Selection::Ids(Arc::new(ids)) }
    }
}

impl From<Vec<DenseVec>> for CorpusStore {
    fn from(rows: Vec<DenseVec>) -> Self {
        CorpusStore::from_rows(rows)
    }
}

/// A borrowed, normalized corpus row.
#[derive(Clone, Copy)]
pub struct VecRef<'a> {
    data: &'a [f32],
}

impl<'a> VecRef<'a> {
    pub fn as_slice(&self) -> &'a [f32] {
        self.data
    }

    pub fn dim(&self) -> usize {
        self.data.len()
    }

    /// Cosine similarity to another row (both pre-normalized).
    pub fn dot(&self, other: VecRef<'_>) -> f64 {
        dot_slice(self.data, other.data)
    }

    pub fn to_owned(self) -> DenseVec {
        DenseVec::from_normalized(self.data.to_vec())
    }
}

#[derive(Clone)]
enum Selection {
    /// Contiguous store rows `[start, end)`; local id `i` is row `start + i`.
    Rows(usize, usize),
    /// Explicit store rows; local id `i` is row `ids[i]`.
    Ids(Arc<Vec<u32>>),
}

/// A zero-copy window onto a [`CorpusStore`]: the unit indexes build from,
/// shards own, and the PJRT runtime feeds from. Local ids `0..len` map to
/// store rows through the selection.
#[derive(Clone)]
pub struct CorpusView {
    store: CorpusStore,
    sel: Selection,
}

impl CorpusView {
    pub fn len(&self) -> usize {
        match &self.sel {
            Selection::Rows(lo, hi) => hi - lo,
            Selection::Ids(ids) => ids.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dim(&self) -> usize {
        self.store.dim()
    }

    pub fn store(&self) -> &CorpusStore {
        &self.store
    }

    /// Store row index backing local id `local`.
    pub fn store_row(&self, local: u32) -> usize {
        match &self.sel {
            Selection::Rows(lo, hi) => {
                let r = *lo + local as usize;
                assert!(r < *hi, "local id {local} out of view of {} rows", *hi - *lo);
                r
            }
            Selection::Ids(ids) => ids[local as usize] as usize,
        }
    }

    /// Local row `local` as a borrowed slice (zero-copy).
    pub fn row(&self, local: u32) -> &[f32] {
        self.store.row(self.store_row(local))
    }

    pub fn vec_ref(&self, local: u32) -> VecRef<'_> {
        VecRef { data: self.row(local) }
    }

    /// Owned copy of local row `local`.
    pub fn vec(&self, local: u32) -> DenseVec {
        DenseVec::from_normalized(self.row(local).to_vec())
    }

    /// The view's rows as one contiguous row-major slab, if the selection is
    /// a row range — the zero-copy path into the PJRT input buffer.
    pub fn as_contiguous(&self) -> Option<&[f32]> {
        match &self.sel {
            Selection::Rows(lo, hi) => {
                let d = self.dim();
                Some(&self.store.flat()[lo * d..hi * d])
            }
            Selection::Ids(_) => None,
        }
    }

    /// Contiguous slab, gathering through the id list only when the view is
    /// non-contiguous.
    pub fn contiguous_or_gather(&self) -> Cow<'_, [f32]> {
        match self.as_contiguous() {
            Some(slab) => Cow::Borrowed(slab),
            None => {
                let d = self.dim();
                let mut out = Vec::with_capacity(self.len() * d);
                for i in 0..self.len() as u32 {
                    out.extend_from_slice(self.row(i));
                }
                Cow::Owned(out)
            }
        }
    }

    /// Sub-view over local rows `[lo, hi)` (engine tiling).
    pub fn slice_rows(&self, lo: usize, hi: usize) -> CorpusView {
        assert!(lo <= hi && hi <= self.len(), "slice_rows {lo}..{hi} out of {}", self.len());
        let sel = match &self.sel {
            Selection::Rows(start, _) => Selection::Rows(start + lo, start + hi),
            Selection::Ids(ids) => Selection::Ids(Arc::new(ids[lo..hi].to_vec())),
        };
        CorpusView { store: self.store.clone(), sel }
    }

    /// Invoke `f(local_id, sim)` for every row of the view, walking the
    /// contiguous buffer two rows per `dot2` pass (query streamed once
    /// per pair), scalar tail for an odd final row.
    pub fn for_each_sim(&self, q: &[f32], mut f: impl FnMut(u32, f64)) {
        let d = self.dim();
        assert_eq!(q.len(), d, "query dimension {} != corpus dimension {d}", q.len());
        match &self.sel {
            Selection::Rows(lo, hi) => {
                let (lo, hi) = (*lo, *hi);
                let flat = &self.store.flat()[lo * d..hi * d];
                let n = hi - lo;
                if d == 0 {
                    for i in 0..n {
                        f(i as u32, 0.0);
                    }
                    return;
                }
                let mut i = 0usize;
                while i + 2 <= n {
                    let b = i * d;
                    let (s0, s1) = dot2(q, &flat[b..b + d], &flat[b + d..b + 2 * d]);
                    f(i as u32, s0);
                    f((i + 1) as u32, s1);
                    i += 2;
                }
                if i < n {
                    f(i as u32, dot_slice(q, &flat[i * d..(i + 1) * d]));
                }
            }
            Selection::Ids(ids) => {
                self.sim_of_rows(q, ids, |pos, s| f(pos as u32, s));
            }
        }
    }

    /// Invoke `f(position, sim)` for the given **local** ids, in order,
    /// gathering rows through the selection in blocks.
    fn sim_of_locals(&self, q: &[f32], locals: &[u32], mut f: impl FnMut(usize, f64)) {
        let d = self.dim();
        assert_eq!(q.len(), d, "query dimension {} != corpus dimension {d}", q.len());
        match &self.sel {
            Selection::Rows(lo, hi) => {
                let (lo, hi) = (*lo, *hi);
                let row = |local: u32| {
                    let r = lo + local as usize;
                    assert!(r < hi, "local id {local} out of view");
                    self.store.row(r)
                };
                let mut i = 0usize;
                while i + 2 <= locals.len() {
                    let (s0, s1) = dot2(q, row(locals[i]), row(locals[i + 1]));
                    f(i, s0);
                    f(i + 1, s1);
                    i += 2;
                }
                if i < locals.len() {
                    f(i, dot_slice(q, row(locals[i])));
                }
            }
            Selection::Ids(ids) => {
                // One indirection through the selection, then the row kernel.
                let rows: Vec<u32> = locals.iter().map(|&l| ids[l as usize]).collect();
                self.sim_of_rows(q, &rows, f);
            }
        }
    }

    /// `f(position, sim)` over absolute store rows (internal).
    fn sim_of_rows(&self, q: &[f32], rows: &[u32], mut f: impl FnMut(usize, f64)) {
        let row = |id: u32| self.store.row(id as usize);
        let mut i = 0usize;
        while i + 2 <= rows.len() {
            let (s0, s1) = dot2(q, row(rows[i]), row(rows[i + 1]));
            f(i, s0);
            f(i + 1, s1);
            i += 2;
        }
        if i < rows.len() {
            f(i, dot_slice(q, row(rows[i])));
        }
    }

    /// Blocked batch dot: similarities of `q` to the given local ids,
    /// replacing `out`'s contents in matching order.
    pub fn dot_batch(&self, q: &[f32], locals: &[u32], out: &mut Vec<f64>) {
        out.clear();
        out.reserve(locals.len());
        self.sim_of_locals(q, locals, |_, s| out.push(s));
    }

    /// Blocked full-view top-k scan: offer every row to `heap`. Returns the
    /// number of exact similarity evaluations (= the view length).
    pub fn scan_topk(&self, q: &[f32], heap: &mut KnnHeap) -> u64 {
        self.for_each_sim(q, |local, s| heap.offer(local, s));
        self.len() as u64
    }

    /// Blocked full-view range scan: push every `(local, sim)` with
    /// `sim >= tau`. Returns the number of exact similarity evaluations.
    pub fn scan_range(&self, q: &[f32], tau: f64, out: &mut Vec<(u32, f64)>) -> u64 {
        self.for_each_sim(q, |local, s| {
            if s >= tau {
                out.push((local, s));
            }
        });
        self.len() as u64
    }

    /// Blocked id-list top-k scan (leaf buckets). Returns evals.
    pub fn scan_ids_topk(&self, q: &[f32], locals: &[u32], heap: &mut KnnHeap) -> u64 {
        self.sim_of_locals(q, locals, |pos, s| heap.offer(locals[pos], s));
        locals.len() as u64
    }

    /// Blocked id-list range scan (leaf buckets). Returns evals.
    pub fn scan_ids_range(
        &self,
        q: &[f32],
        locals: &[u32],
        tau: f64,
        out: &mut Vec<(u32, f64)>,
    ) -> u64 {
        self.sim_of_locals(q, locals, |pos, s| {
            if s >= tau {
                out.push((locals[pos], s));
            }
        });
        locals.len() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::uniform_sphere;

    fn store_of(n: usize, d: usize, seed: u64) -> (CorpusStore, Vec<DenseVec>) {
        let rows = uniform_sphere(n, d, seed);
        (CorpusStore::from_rows(rows.clone()), rows)
    }

    #[test]
    fn from_rows_is_contiguous_and_aliased_by_views() {
        let (store, rows) = store_of(10, 6, 1);
        assert_eq!(store.len(), 10);
        assert_eq!(store.dim(), 6);
        for (i, r) in rows.iter().enumerate() {
            assert_eq!(store.row(i), r.as_slice());
        }
        let v = store.slice(3..7);
        assert_eq!(v.len(), 4);
        // Views alias the buffer: same pointers, no copies.
        assert!(std::ptr::eq(v.row(0), &store.flat()[3 * 6..4 * 6]));
        assert!(std::ptr::eq(
            v.as_contiguous().unwrap(),
            &store.flat()[3 * 6..7 * 6]
        ));
    }

    #[test]
    #[should_panic(expected = "dimension")]
    fn from_rows_rejects_ragged_rows() {
        CorpusStore::from_rows(vec![
            DenseVec::new(vec![1.0, 0.0]),
            DenseVec::new(vec![1.0, 0.0, 0.0]),
        ]);
    }

    #[test]
    #[should_panic(expected = "dimension mismatch")]
    fn dot_slice_rejects_dim_mismatch() {
        dot_slice(&[1.0, 0.0], &[1.0, 0.0, 0.0]);
    }

    #[test]
    fn blocked_kernels_match_dot_slice_bitwise() {
        // Sizes straddling the block and pair boundaries, odd dims for tails.
        for (n, d) in [(1usize, 3usize), (2, 4), (7, 5), (8, 8), (9, 13), (33, 17)] {
            let (store, rows) = store_of(n, d, 42 + n as u64);
            let q = uniform_sphere(1, d, 999).pop().unwrap();
            let view = store.view();
            let mut got = Vec::new();
            view.for_each_sim(q.as_slice(), |local, s| got.push((local, s)));
            assert_eq!(got.len(), n);
            for (local, s) in got {
                let want = dot_slice(q.as_slice(), rows[local as usize].as_slice());
                assert!(
                    s == want,
                    "row {local}: blocked {s:?} != scalar {want:?} (n={n} d={d})"
                );
            }
        }
    }

    #[test]
    fn id_selection_and_dot_batch_match_per_row() {
        let (store, rows) = store_of(20, 9, 7);
        let q = uniform_sphere(1, 9, 1000).pop().unwrap();
        let picked = vec![3u32, 17, 0, 11, 5];
        let view = store.select(picked.clone());
        assert!(view.as_contiguous().is_none());
        let gathered = view.contiguous_or_gather();
        assert_eq!(gathered.len(), picked.len() * 9);

        // Full-view scan over the id selection.
        let mut sims = Vec::new();
        view.for_each_sim(q.as_slice(), |local, s| sims.push((local, s)));
        for (local, s) in sims {
            let want = dot_slice(q.as_slice(), rows[picked[local as usize] as usize].as_slice());
            assert!(s == want);
        }

        // dot_batch over locals of a row-range view.
        let range_view = store.slice(2..18);
        let locals = vec![0u32, 15, 7, 3, 3, 8];
        let mut out = Vec::new();
        range_view.dot_batch(q.as_slice(), &locals, &mut out);
        assert_eq!(out.len(), locals.len());
        for (pos, &s) in out.iter().enumerate() {
            let want =
                dot_slice(q.as_slice(), rows[2 + locals[pos] as usize].as_slice());
            assert!(s == want);
        }
    }

    #[test]
    fn scan_kernels_filter_and_rank() {
        let (store, rows) = store_of(50, 8, 3);
        let view = store.view();
        let q = rows[4].clone();
        let mut out = Vec::new();
        let evals = view.scan_range(q.as_slice(), 0.5, &mut out);
        assert_eq!(evals, 50);
        assert!(out.iter().any(|&(id, _)| id == 4));
        assert!(out.iter().all(|&(_, s)| s >= 0.5));

        let mut heap = KnnHeap::new(5);
        view.scan_topk(q.as_slice(), &mut heap);
        let top = heap.into_sorted();
        assert_eq!(top[0].0, 4);
        assert!((top[0].1 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn store_clone_shares_the_buffer() {
        let (store, _) = store_of(5, 4, 11);
        let clone = store.clone();
        assert!(std::ptr::eq(store.flat(), clone.flat()));
    }

    #[test]
    fn empty_store_is_usable() {
        let store = CorpusStore::from_flat_normalized(Vec::new(), 0);
        assert!(store.is_empty());
        let view = store.view();
        assert_eq!(view.len(), 0);
        assert!(view.as_contiguous().unwrap().is_empty());
    }
}
