//! Synchronization shim layer: the one place in the tree allowed to touch
//! `std::sync::atomic` directly (enforced by `simetra-lint`).
//!
//! Every atomic, yield point, and blocking primitive the crate's concurrent
//! code uses goes through the wrappers in this module. In a normal build
//! each wrapper is a `#[repr(transparent)]`-thin delegate to the `std`
//! primitive with one predicted branch of overhead (a thread-local check).
//! Inside a [`model::explore`] run, the same wrappers become *schedule
//! points*: each operation parks the calling thread and hands control to a
//! deterministic, deviation-bounded scheduler that enumerates thread
//! interleavings and replays them exactly (ADR-010). That is what lets the
//! hazard-pointer [`crate::ingest::swap::SnapshotCell`], the
//! [`crate::obs::ObsRegistry`] hot counters, and the server worker-pool
//! [`queue::RunQueue`] be model-checked by plain `cargo test` with no
//! nightly features and no external tooling.
//!
//! The switch is per-thread and runtime: threads spawned by the model
//! scheduler take the instrumented path, every other thread takes the
//! `std` path. The two coexist safely — instrumented lock acquisition is a
//! `try_lock` spin, which interoperates with real blocking lockers.

// Justification: this module *is* the shim boundary — it must name the raw
// `std` atomics and `std::thread::yield_now` that `clippy.toml` disallows
// everywhere else in the crate.
#![allow(clippy::disallowed_types, clippy::disallowed_methods)]

pub mod model;
pub mod queue;

use std::sync::atomic as std_atomic;
use std::sync::{LockResult, PoisonError, TryLockError};
use std::time::Duration;

pub use std_atomic::Ordering;

macro_rules! shim_atomic_int {
    ($(#[$doc:meta])* $name:ident, $std:ty, $prim:ty) => {
        $(#[$doc])*
        #[derive(Default)]
        #[repr(transparent)]
        pub struct $name($std);

        impl $name {
            #[inline]
            pub const fn new(v: $prim) -> $name {
                $name(<$std>::new(v))
            }

            #[inline]
            pub fn load(&self, order: Ordering) -> $prim {
                model::op();
                self.0.load(order)
            }

            #[inline]
            pub fn store(&self, v: $prim, order: Ordering) {
                model::op();
                self.0.store(v, order)
            }

            #[inline]
            pub fn swap(&self, v: $prim, order: Ordering) -> $prim {
                model::op();
                self.0.swap(v, order)
            }

            #[inline]
            pub fn compare_exchange(
                &self,
                current: $prim,
                new: $prim,
                success: Ordering,
                failure: Ordering,
            ) -> Result<$prim, $prim> {
                model::op();
                self.0.compare_exchange(current, new, success, failure)
            }

            #[inline]
            pub fn fetch_add(&self, v: $prim, order: Ordering) -> $prim {
                model::op();
                self.0.fetch_add(v, order)
            }

            #[inline]
            pub fn fetch_sub(&self, v: $prim, order: Ordering) -> $prim {
                model::op();
                self.0.fetch_sub(v, order)
            }

            #[inline]
            pub fn fetch_max(&self, v: $prim, order: Ordering) -> $prim {
                model::op();
                self.0.fetch_max(v, order)
            }

            /// Exclusive access needs no schedule point: `&mut self` proves
            /// no other thread can race this read.
            #[inline]
            pub fn get_mut(&mut self) -> &mut $prim {
                self.0.get_mut()
            }

            #[inline]
            pub fn into_inner(self) -> $prim {
                self.0.into_inner()
            }
        }

        impl std::fmt::Debug for $name {
            fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                self.0.fmt(f)
            }
        }
    };
}

shim_atomic_int!(
    /// Shim over [`std::sync::atomic::AtomicU64`]; a model schedule point
    /// when the calling thread belongs to a [`model::explore`] run.
    AtomicU64,
    std_atomic::AtomicU64,
    u64
);
shim_atomic_int!(
    /// Shim over [`std::sync::atomic::AtomicUsize`]; a model schedule point
    /// when the calling thread belongs to a [`model::explore`] run.
    AtomicUsize,
    std_atomic::AtomicUsize,
    usize
);

/// Shim over [`std::sync::atomic::AtomicBool`]; a model schedule point when
/// the calling thread belongs to a [`model::explore`] run.
#[derive(Default)]
#[repr(transparent)]
pub struct AtomicBool(std_atomic::AtomicBool);

impl AtomicBool {
    #[inline]
    pub const fn new(v: bool) -> AtomicBool {
        AtomicBool(std_atomic::AtomicBool::new(v))
    }

    #[inline]
    pub fn load(&self, order: Ordering) -> bool {
        model::op();
        self.0.load(order)
    }

    #[inline]
    pub fn store(&self, v: bool, order: Ordering) {
        model::op();
        self.0.store(v, order)
    }

    #[inline]
    pub fn swap(&self, v: bool, order: Ordering) -> bool {
        model::op();
        self.0.swap(v, order)
    }

    /// Exclusive access needs no schedule point (`&mut self`).
    #[inline]
    pub fn get_mut(&mut self) -> &mut bool {
        self.0.get_mut()
    }
}

impl std::fmt::Debug for AtomicBool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.0.fmt(f)
    }
}

/// Shim over [`std::sync::atomic::AtomicPtr`]; a model schedule point when
/// the calling thread belongs to a [`model::explore`] run.
#[repr(transparent)]
pub struct AtomicPtr<T>(std_atomic::AtomicPtr<T>);

impl<T> AtomicPtr<T> {
    #[inline]
    pub const fn new(p: *mut T) -> AtomicPtr<T> {
        AtomicPtr(std_atomic::AtomicPtr::new(p))
    }

    #[inline]
    pub fn load(&self, order: Ordering) -> *mut T {
        model::op();
        self.0.load(order)
    }

    #[inline]
    pub fn store(&self, p: *mut T, order: Ordering) {
        model::op();
        self.0.store(p, order)
    }

    #[inline]
    pub fn swap(&self, p: *mut T, order: Ordering) -> *mut T {
        model::op();
        self.0.swap(p, order)
    }

    /// Exclusive access needs no schedule point (`&mut self`).
    #[inline]
    pub fn get_mut(&mut self) -> &mut *mut T {
        self.0.get_mut()
    }
}

impl<T> std::fmt::Debug for AtomicPtr<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.0.fmt(f)
    }
}

/// Cooperative yield: the `std` yield normally, a *voluntary* schedule
/// point (`Yield` kind — the model's default policy switches threads here
/// without charging a preemption) inside a model run.
#[inline]
pub fn yield_now() {
    if model::active() {
        model::op_yield();
    } else {
        std::thread::yield_now();
    }
}

/// Shim over [`std::sync::Mutex`]. Outside a model run, `lock` delegates
/// to the blocking `std` lock. Inside one it spins on `try_lock` with a
/// yield schedule point per attempt, so the scheduler can run the holder
/// to its release instead of deadlocking the single-stepped execution.
pub struct Mutex<T> {
    inner: std::sync::Mutex<T>,
}

/// Guard for [`Mutex`]; carries its lock so [`Condvar::wait_timeout`] can
/// re-acquire under the model (the `std` guard hides its mutex).
pub struct MutexGuard<'a, T> {
    inner: Option<std::sync::MutexGuard<'a, T>>,
    lock: &'a Mutex<T>,
}

impl<T> Mutex<T> {
    pub const fn new(value: T) -> Mutex<T> {
        Mutex { inner: std::sync::Mutex::new(value) }
    }

    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        if model::active() {
            loop {
                match self.inner.try_lock() {
                    Ok(g) => return Ok(MutexGuard { inner: Some(g), lock: self }),
                    Err(TryLockError::Poisoned(pe)) => {
                        return Err(PoisonError::new(MutexGuard {
                            inner: Some(pe.into_inner()),
                            lock: self,
                        }));
                    }
                    // Contended: let the scheduler run other threads (one
                    // of them holds the lock and will release it).
                    Err(TryLockError::WouldBlock) => model::op_yield(),
                }
            }
        }
        match self.inner.lock() {
            Ok(g) => Ok(MutexGuard { inner: Some(g), lock: self }),
            Err(pe) => Err(PoisonError::new(MutexGuard {
                inner: Some(pe.into_inner()),
                lock: self,
            })),
        }
    }

    pub fn get_mut(&mut self) -> LockResult<&mut T> {
        self.inner.get_mut().map_err(|pe| PoisonError::new(pe.into_inner()))
    }
}

impl<T: std::fmt::Debug> std::fmt::Debug for Mutex<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.inner.fmt(f)
    }
}

impl<T> std::ops::Deref for MutexGuard<'_, T> {
    type Target = T;

    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard holds the lock")
    }
}

impl<T> std::ops::DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard holds the lock")
    }
}

/// Result of [`Condvar::wait_timeout`]. The crate's own type: `std`'s
/// `WaitTimeoutResult` has no public constructor, and the model path must
/// fabricate one for its simulated (always-spurious) wakeups.
#[derive(Clone, Copy, Debug)]
pub struct WaitTimeoutResult {
    timed_out: bool,
}

impl WaitTimeoutResult {
    pub fn timed_out(&self) -> bool {
        self.timed_out
    }
}

/// Shim over [`std::sync::Condvar`]. Under the model, `wait_timeout`
/// releases the lock, yields one schedule point, and re-acquires — i.e.
/// every wait is a spurious wakeup. That is sound (and complete for
/// timeout-polling waiters like [`queue::RunQueue::pop`]): correct condvar
/// code must re-check its predicate in a loop anyway, and modeling waits as
/// spurious lets the bounded scheduler explore waiter/notifier orders
/// without modeling wakeup sets.
pub struct Condvar {
    inner: std::sync::Condvar,
}

impl Condvar {
    pub const fn new() -> Condvar {
        Condvar { inner: std::sync::Condvar::new() }
    }

    pub fn notify_one(&self) {
        model::op();
        self.inner.notify_one();
    }

    pub fn notify_all(&self) {
        model::op();
        self.inner.notify_all();
    }

    #[allow(clippy::type_complexity)] // mirrors the std signature
    pub fn wait_timeout<'a, T>(
        &self,
        mut guard: MutexGuard<'a, T>,
        dur: Duration,
    ) -> LockResult<(MutexGuard<'a, T>, WaitTimeoutResult)> {
        let lock = guard.lock;
        let std_guard = guard.inner.take().expect("guard holds the lock");
        if model::active() {
            drop(std_guard);
            model::op_yield();
            let timed_out = WaitTimeoutResult { timed_out: true };
            return match lock.lock() {
                Ok(g) => Ok((g, timed_out)),
                Err(pe) => Err(PoisonError::new((pe.into_inner(), timed_out))),
            };
        }
        match self.inner.wait_timeout(std_guard, dur) {
            Ok((g, wtr)) => Ok((
                MutexGuard { inner: Some(g), lock },
                WaitTimeoutResult { timed_out: wtr.timed_out() },
            )),
            Err(pe) => {
                let (g, wtr) = pe.into_inner();
                Err(PoisonError::new((
                    MutexGuard { inner: Some(g), lock },
                    WaitTimeoutResult { timed_out: wtr.timed_out() },
                )))
            }
        }
    }
}

impl Default for Condvar {
    fn default() -> Condvar {
        Condvar::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn atomics_delegate_outside_a_model_run() {
        let a = AtomicU64::new(5);
        assert_eq!(a.fetch_add(2, Ordering::Relaxed), 5);
        assert_eq!(a.load(Ordering::SeqCst), 7);
        assert_eq!(a.swap(1, Ordering::SeqCst), 7);
        assert_eq!(a.fetch_max(9, Ordering::Relaxed), 1);
        let b = AtomicBool::new(false);
        assert!(!b.swap(true, Ordering::SeqCst));
        assert!(b.load(Ordering::SeqCst));
        let u = AtomicUsize::new(0);
        assert!(u.compare_exchange(0, 3, Ordering::SeqCst, Ordering::SeqCst).is_ok());
        assert_eq!(u.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn mutex_and_condvar_delegate_outside_a_model_run() {
        let m = Arc::new(Mutex::new(0u32));
        let cv = Arc::new(Condvar::new());
        {
            let mut g = m.lock().unwrap();
            *g += 1;
        }
        let g = m.lock().unwrap();
        let (g, wtr) = cv.wait_timeout(g, Duration::from_millis(1)).unwrap();
        assert!(wtr.timed_out());
        assert_eq!(*g, 1);
    }

    #[test]
    fn guard_releases_on_drop() {
        let m = Mutex::new(7u32);
        drop(m.lock().unwrap());
        assert_eq!(*m.lock().unwrap(), 7);
    }
}
