//! Deterministic, deviation-bounded concurrency model checker (ADR-010).
//!
//! [`explore`] runs a small multi-threaded scenario over and over, each
//! time under a different thread interleaving, until the bounded schedule
//! space is exhausted or an invariant breaks. Scheduling is *serialized*:
//! real OS threads are spawned per execution, but only one is ever granted
//! the CPU at a time, and every shim operation in [`crate::sync`] parks
//! the thread until the scheduler grants it the next step. Because the
//! program under test is deterministic apart from thread order, recording
//! the chosen thread per step yields an exactly replayable schedule — the
//! classic stateless-exploration design (CHESS-style iterative context
//! bounding) rather than full DPOR: schedules are enumerated depth-first,
//! each charged one unit of [`Config::max_preemptions`] per *deviation*
//! from the deterministic fair default policy (run the granted thread
//! until it yields, blocks, or finishes; then rotate round-robin). A
//! deviation is an involuntary preemption at an atomic op or an
//! alternative pick at a voluntary switch point (`yield_now`, lock
//! contention, condvar waits); returning to the default policy costs
//! nothing. Charging voluntary-switch alternatives too is what keeps
//! spin-wait loops (hazard scans, condvar poll loops) from exploding the
//! space — the default schedule is fair, so only bounded departures from
//! it are enumerated, and for the protocols in this crate the 2-deviation
//! space already covers every published-vs-reclaimed race the
//! hazard-pointer cell can express (see the broken-cell test in
//! `tests/model_checker.rs`, which the checker catches with 1 deviation).
//!
//! Memory-reclamation invariants come from three hooks the code under test
//! calls around its `unsafe` reclamation points — [`note_alloc`],
//! [`note_free`], [`note_deref`] — each a schedule point of its own, so a
//! writer's free can interleave *between* a reader's re-validation and its
//! dereference if the protocol allows it. The checker fails an execution
//! on use-after-free, double reclaim, or (at thread exit) leaked
//! retirements. All hooks are no-ops on threads that do not belong to a
//! model run.

use std::any::Any;
use std::cell::RefCell;
use std::collections::HashMap;
use std::panic::{self, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::Duration;

/// What a parked thread is about to do. `Yield` marks voluntary
/// reschedule points (spin backoff, lock contention, condvar waits): the
/// default policy rotates threads there, and a repeat grant right after a
/// yield is pruned as a stutter (nothing ran, so its re-check is a no-op).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpKind {
    Op,
    Yield,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum TState {
    /// Spawned but not yet parked at the start barrier.
    Starting,
    /// Granted the current step; executing up to its next schedule point.
    Running,
    /// Parked at a schedule point, waiting for a grant.
    Parked(OpKind),
    Finished,
}

/// Exploration bounds. The defaults suit the scenarios in this repo's
/// model tests: small thread counts, a few dozen schedule points each.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    /// Maximum scheduling deviations per execution: each step whose
    /// granted thread differs from the fair default policy's pick —
    /// an involuntary preemption at an op, or an alternative choice at
    /// a voluntary yield — spends one unit.
    pub max_preemptions: usize,
    /// Per-execution step cap: trips the livelock guard when a schedule
    /// stops making progress (e.g. a spin loop the schedule starves).
    pub max_steps: u64,
    /// Total executions cap; exceeding it reports `complete: false`.
    pub max_execs: u64,
}

impl Default for Config {
    fn default() -> Config {
        Config { max_preemptions: 2, max_steps: 20_000, max_execs: 200_000 }
    }
}

/// Outcome of an [`explore`] call.
#[derive(Debug)]
pub struct Report {
    /// Executions actually run.
    pub executions: u64,
    /// Whether the bounded schedule space was exhausted (false when the
    /// execution cap tripped or a failure stopped the search).
    pub complete: bool,
    /// The first failing schedule found, if any.
    pub failure: Option<Failure>,
    /// Total [`note_alloc`] calls summed over all executions.
    pub allocs_total: u64,
    /// Total [`note_free`] calls summed over all executions; equals
    /// `allocs_total` whenever no execution leaked.
    pub frees_total: u64,
}

/// A failing schedule: the invariant message plus the exact sequence of
/// thread ids granted per step, for replay while debugging.
#[derive(Debug)]
pub struct Failure {
    pub message: String,
    pub schedule: Vec<usize>,
}

struct Inner {
    states: Vec<TState>,
    granted: Option<usize>,
    abort: bool,
    failure: Option<String>,
    /// Tracked reclamation units: address -> currently live. An address
    /// freed and then returned again by the allocator flips back to live.
    allocs: HashMap<usize, bool>,
    allocs_total: u64,
    frees_total: u64,
    steps: u64,
    max_steps: u64,
}

struct Shared {
    m: Mutex<Inner>,
    cv: Condvar,
}

struct Participant {
    shared: Arc<Shared>,
    tid: usize,
}

thread_local! {
    static PARTICIPANT: RefCell<Option<Participant>> = const { RefCell::new(None) };
}

/// At most one [`explore`] runs at a time (held for the whole search, so
/// concurrent `cargo test` threads serialize their model runs instead of
/// cross-talking through the session global below).
static EXPLORE_LOCK: Mutex<()> = Mutex::new(());

/// Fast guard for the reclamation hooks on non-participant threads: true
/// exactly while an execution's session is installed. Raw `std` atomic —
/// this module is the model's own machinery, not code under test.
static SESSION_ACTIVE: std::sync::atomic::AtomicBool =
    std::sync::atomic::AtomicBool::new(false);

/// The running execution's session, visible to non-participant threads.
/// Scenario factories run on the exploring thread *before* any model
/// thread spawns, yet the state they build (e.g. a `SnapshotCell`'s
/// initial box) must be tracked — otherwise its eventual reclamation by a
/// participant would look like a foreign free.
static SESSION: Mutex<Option<Arc<Shared>>> = Mutex::new(None);

fn current_session() -> Option<Arc<Shared>> {
    // Skip while unwinding for the same reason as [`active`]: a hook
    // firing from a drop during a panic must not panic again.
    if std::thread::panicking() || !SESSION_ACTIVE.load(std::sync::atomic::Ordering::Acquire) {
        return None;
    }
    SESSION.lock().unwrap_or_else(|e| e.into_inner()).clone()
}

/// Installs a session for the duration of one execution; cleared on drop
/// so every exit path (including stalls) tears it down.
struct SessionGuard;

impl SessionGuard {
    fn install(shared: &Arc<Shared>) -> SessionGuard {
        *SESSION.lock().unwrap_or_else(|e| e.into_inner()) = Some(shared.clone());
        SESSION_ACTIVE.store(true, std::sync::atomic::Ordering::Release);
        SessionGuard
    }
}

impl Drop for SessionGuard {
    fn drop(&mut self) {
        SESSION_ACTIVE.store(false, std::sync::atomic::Ordering::Release);
        *SESSION.lock().unwrap_or_else(|e| e.into_inner()) = None;
    }
}

/// Panic payload used to unwind model threads without touching the global
/// panic hook: aborted executions and detected violations must not spray
/// backtraces for schedules the checker handles itself.
struct ModelAbort;

/// Whether the calling thread belongs to a running [`explore`] execution.
/// False while the thread is unwinding: drops that run during an abort
/// (or an assertion failure) must not re-enter the scheduler — parking,
/// or unwinding a second time from a schedule point, inside a panic
/// would escalate to a process abort.
#[inline]
pub fn active() -> bool {
    !std::thread::panicking() && PARTICIPANT.with(|p| p.borrow().is_some())
}

/// Schedule point for an ordinary shim operation.
#[inline]
pub(crate) fn op() {
    if active() {
        schedule_point(OpKind::Op);
    }
}

/// Schedule point for a voluntary yield (the default policy rotates here).
#[inline]
pub(crate) fn op_yield() {
    if active() {
        schedule_point(OpKind::Yield);
    }
}

fn with_participant<R>(f: impl FnOnce(&Arc<Shared>, usize) -> R) -> Option<R> {
    PARTICIPANT.with(|p| p.borrow().as_ref().map(|q| f(&q.shared, q.tid)))
}

fn abort_unwind() -> ! {
    panic::resume_unwind(Box::new(ModelAbort))
}

/// Record a violation, wake everyone, and unwind the current thread.
fn violation(shared: &Arc<Shared>, mut g: MutexGuard<'_, Inner>, message: String) -> ! {
    g.abort = true;
    if g.failure.is_none() {
        g.failure = Some(message);
    }
    shared.cv.notify_all();
    drop(g);
    abort_unwind()
}

fn schedule_point(kind: OpKind) {
    let part = with_participant(|shared, tid| (shared.clone(), tid));
    let Some((shared, tid)) = part else { return };
    let mut g = shared.m.lock().unwrap();
    if g.abort {
        drop(g);
        abort_unwind();
    }
    g.states[tid] = TState::Parked(kind);
    shared.cv.notify_all();
    loop {
        if g.abort {
            drop(g);
            abort_unwind();
        }
        if g.granted == Some(tid) {
            break;
        }
        g = shared.cv.wait(g).unwrap();
    }
    g.granted = None;
    g.states[tid] = TState::Running;
    g.steps += 1;
    if g.steps > g.max_steps {
        let max = g.max_steps;
        violation(&shared, g, format!("livelock guard: schedule exceeded {max} steps"));
    }
}

/// Register a reclamation unit (e.g. the `Box` a `SnapshotCell` publishes).
/// A schedule point of its own on model threads, so allocation interleaves
/// like any other op; on non-participant threads it records into the
/// running session, if any (scenario factories allocate before threads
/// spawn), and is free otherwise.
pub fn note_alloc(addr: usize) {
    if active() {
        schedule_point(OpKind::Op);
        with_participant(|shared, _| {
            let mut g = shared.m.lock().unwrap();
            g.allocs.insert(addr, true);
            g.allocs_total += 1;
        });
    } else if let Some(shared) = current_session() {
        let mut g = shared.m.lock().unwrap();
        g.allocs.insert(addr, true);
        g.allocs_total += 1;
    }
}

/// Record reclamation of a unit. Fails the execution on double reclaim.
/// Call *immediately before* the actual free so the checker sees the
/// free at the earliest point it can race a reader.
pub fn note_free(addr: usize) {
    if active() {
        schedule_point(OpKind::Op);
        with_participant(|shared, tid| {
            let mut g = shared.m.lock().unwrap();
            if g.allocs.get(&addr) == Some(&true) {
                g.allocs.insert(addr, false);
                g.frees_total += 1;
            } else {
                violation(
                    shared,
                    g,
                    format!("double reclaim: thread {tid} freed {addr:#x} twice"),
                );
            }
        });
    } else if let Some(shared) = current_session() {
        let mut g = shared.m.lock().unwrap();
        if g.allocs.get(&addr) == Some(&true) {
            g.allocs.insert(addr, false);
            g.frees_total += 1;
        } else {
            panic!("double reclaim (off-schedule): {addr:#x} freed twice");
        }
    }
}

/// Assert a tracked unit is still live before dereferencing it. Fails the
/// execution with a use-after-free otherwise. A schedule point of its own,
/// so a racing free can land between a protocol's validation and its
/// dereference if the protocol allows that schedule.
pub fn note_deref(addr: usize) {
    if active() {
        schedule_point(OpKind::Op);
        with_participant(|shared, tid| {
            let g = shared.m.lock().unwrap();
            if g.allocs.get(&addr) != Some(&true) {
                violation(
                    shared,
                    g,
                    format!("use-after-free: thread {tid} dereferenced freed {addr:#x}"),
                );
            }
        });
    } else if let Some(shared) = current_session() {
        let g = shared.m.lock().unwrap();
        if g.allocs.get(&addr) != Some(&true) {
            panic!("use-after-free (off-schedule): dereferenced freed {addr:#x}");
        }
    }
}

#[derive(Clone)]
struct StepRec {
    chosen: usize,
    /// Parked threads (tid, pending op kind) the scheduler could have
    /// picked at this step, in tid order.
    runnable: Vec<(usize, OpKind)>,
}

struct Outcome {
    trace: Vec<StepRec>,
    failure: Option<String>,
    allocs: u64,
    frees: u64,
}

/// Deviation-free default policy: keep running the current thread; at a
/// voluntary yield (or when it blocks/finishes), rotate round-robin.
fn default_choice(prev: Option<usize>, runnable: &[(usize, OpKind)]) -> usize {
    if let Some(p) = prev {
        if runnable.iter().any(|&(t, k)| t == p && k != OpKind::Yield) {
            return p;
        }
        if let Some(&(t, _)) = runnable.iter().find(|&&(t, _)| t > p) {
            return t;
        }
    }
    runnable[0].0
}

fn panic_message(payload: &(dyn Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "model thread panicked".to_string()
    }
}

fn runner(shared: Arc<Shared>, tid: usize, body: Box<dyn FnOnce() + Send>) {
    PARTICIPANT.with(|p| {
        *p.borrow_mut() = Some(Participant { shared: shared.clone(), tid });
    });
    let result = panic::catch_unwind(AssertUnwindSafe(|| {
        // Start barrier: the scheduler controls the first step too.
        schedule_point(OpKind::Op);
        body();
    }));
    PARTICIPANT.with(|p| *p.borrow_mut() = None);
    let mut g = shared.m.lock().unwrap();
    g.states[tid] = TState::Finished;
    if let Err(payload) = result {
        if !payload.is::<ModelAbort>() {
            g.abort = true;
            if g.failure.is_none() {
                g.failure = Some(panic_message(payload.as_ref()));
            }
        }
    }
    shared.cv.notify_all();
}

/// How long the scheduler waits for quiescence before declaring the
/// execution stalled (a thread blocked outside any schedule point — e.g.
/// on a raw `std` lock held across a shim op, which the model cannot
/// single-step through).
const STALL_TIMEOUT: Duration = Duration::from_secs(30);

fn run_one<F>(cfg: &Config, prefix: &[usize], scenario: &mut F) -> Outcome
where
    F: FnMut() -> Vec<Box<dyn FnOnce() + Send>>,
{
    let shared = Arc::new(Shared {
        m: Mutex::new(Inner {
            states: Vec::new(),
            granted: None,
            abort: false,
            failure: None,
            allocs: HashMap::new(),
            allocs_total: 0,
            frees_total: 0,
            steps: 0,
            max_steps: cfg.max_steps,
        }),
        cv: Condvar::new(),
    });
    // Install the session before building the scenario: state constructed
    // by the factory (initial boxes etc.) must be tracked by the hooks.
    let _session = SessionGuard::install(&shared);
    let bodies = scenario();
    let n = bodies.len();
    assert!(n > 0, "model scenario needs at least one thread");
    shared.m.lock().unwrap().states = vec![TState::Starting; n];
    let mut handles = Vec::with_capacity(n);
    for (tid, body) in bodies.into_iter().enumerate() {
        let shared = shared.clone();
        handles.push(std::thread::spawn(move || runner(shared, tid, body)));
    }

    let mut trace: Vec<StepRec> = Vec::new();
    let mut prev: Option<usize> = None;
    let mut stalled = false;
    let outcome = loop {
        let mut g = shared.m.lock().unwrap();
        loop {
            if g.abort {
                if g.states.iter().all(|s| matches!(s, TState::Finished)) {
                    break;
                }
                // Wake parked threads so they observe the abort and exit.
                shared.cv.notify_all();
            } else if g.granted.is_none()
                && g.states.iter().all(|s| matches!(s, TState::Parked(_) | TState::Finished))
            {
                break;
            }
            let (ng, to) = shared.cv.wait_timeout(g, STALL_TIMEOUT).unwrap();
            g = ng;
            if to.timed_out() {
                stalled = true;
                break;
            }
        }
        if stalled {
            g.abort = true;
            if g.failure.is_none() {
                g.failure = Some(
                    "model stall: a thread blocked outside any schedule point \
                     (raw lock held across a shim operation?)"
                        .to_string(),
                );
            }
            shared.cv.notify_all();
            break Outcome {
                trace: trace.clone(),
                failure: g.failure.clone(),
                allocs: g.allocs_total,
                frees: g.frees_total,
            };
        }
        if g.abort {
            break Outcome {
                trace: trace.clone(),
                failure: g.failure.clone(),
                allocs: g.allocs_total,
                frees: g.frees_total,
            };
        }
        let runnable: Vec<(usize, OpKind)> = g
            .states
            .iter()
            .enumerate()
            .filter_map(|(t, s)| match s {
                TState::Parked(k) => Some((t, *k)),
                _ => None,
            })
            .collect();
        if runnable.is_empty() {
            // All threads finished cleanly: check for leaked retirements.
            let leaked = g.allocs.values().filter(|&&live| live).count();
            let failure = if leaked > 0 {
                Some(format!("leaked retirement: {leaked} allocation(s) never reclaimed"))
            } else {
                None
            };
            break Outcome {
                trace: trace.clone(),
                failure,
                allocs: g.allocs_total,
                frees: g.frees_total,
            };
        }
        let step = trace.len();
        let chosen = if step < prefix.len() {
            let c = prefix[step];
            if !runnable.iter().any(|&(t, _)| t == c) {
                // Replay diverged: the scenario is not deterministic under
                // its schedule (time, randomness, or address-dependent
                // branching leaked in). Surface it as a failure.
                g.abort = true;
                if g.failure.is_none() {
                    g.failure = Some(format!(
                        "nondeterministic replay: thread {c} not runnable at step {step}"
                    ));
                }
                shared.cv.notify_all();
                drop(g);
                continue;
            }
            c
        } else {
            default_choice(prev, &runnable)
        };
        trace.push(StepRec { chosen, runnable });
        prev = Some(chosen);
        g.granted = Some(chosen);
        shared.cv.notify_all();
    };
    if !stalled {
        for h in handles {
            let _ = h.join();
        }
    }
    outcome
}

/// Enumerate unexplored sibling choices of `trace` (depth-first, at steps
/// not fixed by `prefix`) whose deviation count stays within bounds.
///
/// A step's cost is 1 when its granted thread differs from what
/// [`default_choice`] would pick there, else 0. Charging voluntary-switch
/// alternatives (not just op preemptions) bounds the enumeration of
/// spin-wait interleavings: without it, depth-first search burrows into
/// ever-longer reorderings of side-effect-free yield loops — condvar
/// polls, hazard scans — until the livelock guard misfires on perfectly
/// clean code. With it, every explored schedule is the fair default plus
/// at most `max_preemptions` departures, so clean scenarios terminate and
/// the budget is spent near the ops where races actually live.
fn push_branches(cfg: &Config, prefix: &[usize], trace: &[StepRec], frames: &mut Vec<Vec<usize>>) {
    let mut deviations = 0usize;
    for i in 0..trace.len() {
        let prev = if i == 0 { None } else { Some(&trace[i - 1]) };
        let default = default_choice(prev.map(|p| p.chosen), &trace[i].runnable);
        if i >= prefix.len() {
            for &(alt, _) in &trace[i].runnable {
                if alt == trace[i].chosen {
                    continue;
                }
                // Stutter pruning: re-granting a thread parked at a
                // voluntary yield with nothing run in between just re-runs
                // its (side-effect-free) spin check against unchanged
                // state; the yielder stays eligible at every later step.
                if let Some(prev) = prev {
                    if alt == prev.chosen
                        && trace[i].runnable.len() > 1
                        && trace[i]
                            .runnable
                            .iter()
                            .any(|&(t, k)| t == prev.chosen && k == OpKind::Yield)
                    {
                        continue;
                    }
                }
                let extra = usize::from(alt != default);
                if deviations + extra <= cfg.max_preemptions {
                    let mut branch: Vec<usize> =
                        trace[..i].iter().map(|s| s.chosen).collect();
                    branch.push(alt);
                    frames.push(branch);
                }
            }
        }
        if trace[i].chosen != default {
            deviations += 1;
        }
    }
}

/// Explore the bounded schedule space of a scenario.
///
/// `scenario` is called once per execution and returns the thread bodies
/// (fresh state each time — typically closures over a new `Arc`'d value).
/// The scenario must be deterministic apart from thread interleaving.
/// Returns after the space is exhausted, [`Config::max_execs`] trips, or
/// the first failing schedule (invariant panic, use-after-free, double
/// reclaim, leaked retirement, or livelock guard).
pub fn explore<F>(cfg: Config, mut scenario: F) -> Report
where
    F: FnMut() -> Vec<Box<dyn FnOnce() + Send>>,
{
    let _serial = EXPLORE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let mut frames: Vec<Vec<usize>> = vec![Vec::new()];
    let mut executions = 0u64;
    let mut allocs_total = 0u64;
    let mut frees_total = 0u64;
    while let Some(prefix) = frames.pop() {
        if executions >= cfg.max_execs {
            return Report {
                executions,
                complete: false,
                failure: None,
                allocs_total,
                frees_total,
            };
        }
        executions += 1;
        let outcome = run_one(&cfg, &prefix, &mut scenario);
        allocs_total += outcome.allocs;
        frees_total += outcome.frees;
        if let Some(message) = outcome.failure {
            let schedule = outcome.trace.iter().map(|s| s.chosen).collect();
            return Report {
                executions,
                complete: false,
                failure: Some(Failure { message, schedule }),
                allocs_total,
                frees_total,
            };
        }
        push_branches(&cfg, &prefix, &outcome.trace, &mut frames);
    }
    Report { executions, complete: true, failure: None, allocs_total, frees_total }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sync::{AtomicU64, Ordering};

    fn quick() -> Config {
        Config { max_preemptions: 2, max_steps: 5_000, max_execs: 50_000 }
    }

    #[test]
    fn single_thread_runs_once() {
        let report = explore(quick(), || {
            vec![Box::new(|| {
                let a = AtomicU64::new(0);
                a.store(1, Ordering::SeqCst);
                assert_eq!(a.load(Ordering::SeqCst), 1);
            }) as Box<dyn FnOnce() + Send>]
        });
        assert!(report.complete, "{report:?}");
        assert_eq!(report.executions, 1);
        assert!(report.failure.is_none(), "{report:?}");
    }

    #[test]
    fn explores_multiple_interleavings_of_two_writers() {
        let report = explore(quick(), || {
            let shared = std::sync::Arc::new(AtomicU64::new(0));
            (0..2u64)
                .map(|i| {
                    let shared = shared.clone();
                    Box::new(move || {
                        shared.fetch_add(i + 1, Ordering::SeqCst);
                    }) as Box<dyn FnOnce() + Send>
                })
                .collect()
        });
        assert!(report.complete, "{report:?}");
        assert!(report.failure.is_none(), "{report:?}");
        assert!(report.executions > 1, "expected >1 interleaving, got {}", report.executions);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // explores hundreds of executions — too slow under Miri
    fn finds_a_racy_read_modify_write() {
        // Classic lost update: load + store instead of fetch_add. The
        // checker must find a schedule where an increment disappears.
        let report = explore(quick(), || {
            let shared = std::sync::Arc::new(AtomicU64::new(0));
            let done = std::sync::Arc::new(AtomicU64::new(0));
            let mut bodies: Vec<Box<dyn FnOnce() + Send>> = (0..2)
                .map(|_| {
                    let shared = shared.clone();
                    let done = done.clone();
                    Box::new(move || {
                        let v = shared.load(Ordering::SeqCst);
                        shared.store(v + 1, Ordering::SeqCst);
                        done.fetch_add(1, Ordering::SeqCst);
                    }) as Box<dyn FnOnce() + Send>
                })
                .collect();
            let check = shared.clone();
            bodies.push(Box::new(move || {
                while done.load(Ordering::SeqCst) < 2 {
                    crate::sync::yield_now();
                }
                let v = check.load(Ordering::SeqCst);
                assert_eq!(v, 2, "lost update: counter is {v}");
            }));
            bodies
        });
        let failure = report.failure.expect("checker must find the lost update");
        assert!(failure.message.contains("lost update"), "{}", failure.message);
        assert!(!failure.schedule.is_empty());
    }

    #[test]
    fn reclamation_hooks_catch_double_free() {
        let report = explore(quick(), || {
            vec![Box::new(|| {
                note_alloc(0x1000);
                note_free(0x1000);
                note_free(0x1000);
            }) as Box<dyn FnOnce() + Send>]
        });
        let failure = report.failure.expect("double reclaim must be caught");
        assert!(failure.message.contains("double reclaim"), "{}", failure.message);
    }

    #[test]
    fn reclamation_hooks_catch_leaks() {
        let report = explore(quick(), || {
            vec![Box::new(|| {
                note_alloc(0x2000);
            }) as Box<dyn FnOnce() + Send>]
        });
        let failure = report.failure.expect("leak must be caught");
        assert!(failure.message.contains("leaked retirement"), "{}", failure.message);
    }

    #[test]
    fn reclamation_hooks_catch_use_after_free() {
        let report = explore(quick(), || {
            vec![Box::new(|| {
                note_alloc(0x3000);
                note_free(0x3000);
                note_deref(0x3000);
            }) as Box<dyn FnOnce() + Send>]
        });
        let failure = report.failure.expect("use-after-free must be caught");
        assert!(failure.message.contains("use-after-free"), "{}", failure.message);
    }

    #[test]
    fn livelock_guard_trips_on_unbounded_spin() {
        let report = explore(Config { max_preemptions: 0, max_steps: 200, max_execs: 10 }, || {
            let flag = std::sync::Arc::new(crate::sync::AtomicBool::new(false));
            vec![{
                let flag = flag.clone();
                Box::new(move || {
                    // Nobody ever sets the flag: spins until the guard.
                    while !flag.load(Ordering::SeqCst) {
                        crate::sync::yield_now();
                    }
                }) as Box<dyn FnOnce() + Send>
            }]
        });
        let failure = report.failure.expect("livelock guard must trip");
        assert!(failure.message.contains("livelock"), "{}", failure.message);
    }

    #[test]
    fn deviation_budget_gates_alternative_schedules() {
        // Two threads that fetch_add / yield / fetch_add. At budget 0 the
        // only explored schedule is the fair default — exactly one
        // execution, and it must run clean. Granting one deviation opens
        // the alternative orderings around the yield points.
        let scenario = || {
            let shared = std::sync::Arc::new(AtomicU64::new(0));
            (0..2u64)
                .map(|_| {
                    let shared = shared.clone();
                    Box::new(move || {
                        shared.fetch_add(1, Ordering::SeqCst);
                        crate::sync::yield_now();
                        shared.fetch_add(1, Ordering::SeqCst);
                    }) as Box<dyn FnOnce() + Send>
                })
                .collect::<Vec<_>>()
        };
        let tight = explore(
            Config { max_preemptions: 0, max_steps: 1_000, max_execs: 1_000 },
            scenario,
        );
        assert!(tight.complete, "{tight:?}");
        assert!(tight.failure.is_none(), "{tight:?}");
        assert_eq!(tight.executions, 1, "budget 0 must pin the default schedule");

        let loose = explore(
            Config { max_preemptions: 1, max_steps: 1_000, max_execs: 1_000 },
            scenario,
        );
        assert!(loose.complete, "{loose:?}");
        assert!(loose.failure.is_none(), "{loose:?}");
        assert!(loose.executions > 1, "budget 1 must branch: {}", loose.executions);
    }
}
