//! Generic stoppable run queue: the blocking work-distribution primitive
//! behind the server worker pool (ADR-008), extracted here so the model
//! checker can explore its push/pop/stop interleavings directly.
//!
//! Built entirely on the [`crate::sync`] shims, so inside a
//! [`super::model::explore`] run every lock acquisition, condvar wait, and
//! stop-flag access is a schedule point.

use std::collections::VecDeque;
use std::time::Duration;

use super::{AtomicBool, Condvar, Mutex, Ordering};

/// A multi-producer multi-consumer FIFO with a stop switch.
///
/// `pop` blocks (polling its condvar with a caller-chosen timeout, so a
/// missed wakeup can never strand a consumer) until an item or the stop
/// flag arrives; after [`RunQueue::stop`], every `pop` returns `None`
/// forever, even if items remain — callers drain leftovers explicitly via
/// [`RunQueue::drain`] and decide their fate (the server drops queued
/// connections on shutdown).
pub struct RunQueue<T> {
    items: Mutex<VecDeque<T>>,
    ready: Condvar,
    stopped: AtomicBool,
}

impl<T> RunQueue<T> {
    pub fn new() -> RunQueue<T> {
        RunQueue {
            items: Mutex::new(VecDeque::new()),
            ready: Condvar::new(),
            stopped: AtomicBool::new(false),
        }
    }

    /// Enqueue an item and wake one consumer. Returns the queue length
    /// right after the push (under the lock), for gauge reporting.
    pub fn push(&self, item: T) -> usize {
        let mut q = self.items.lock().unwrap();
        q.push_back(item);
        let len = q.len();
        drop(q);
        self.ready.notify_one();
        len
    }

    /// Dequeue the oldest item, waiting until one arrives or the queue is
    /// stopped. `poll` bounds each condvar wait so a consumer re-checks
    /// the stop flag at least that often. Returns the item and the queue
    /// length right after the pop, or `None` once stopped.
    pub fn pop(&self, poll: Duration) -> Option<(T, usize)> {
        let mut q = self.items.lock().unwrap();
        loop {
            if self.stopped.load(Ordering::SeqCst) {
                return None;
            }
            if let Some(item) = q.pop_front() {
                let len = q.len();
                return Some((item, len));
            }
            q = self.ready.wait_timeout(q, poll).unwrap().0;
        }
    }

    /// Flip the stop switch and wake every consumer. Idempotent.
    pub fn stop(&self) {
        self.stopped.store(true, Ordering::SeqCst);
        self.ready.notify_all();
    }

    pub fn stopping(&self) -> bool {
        self.stopped.load(Ordering::SeqCst)
    }

    /// Remove and return everything still queued (shutdown path).
    pub fn drain(&self) -> Vec<T> {
        let mut q = self.items.lock().unwrap();
        q.drain(..).collect()
    }

    pub fn len(&self) -> usize {
        self.items.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Default for RunQueue<T> {
    fn default() -> RunQueue<T> {
        RunQueue::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    const POLL: Duration = Duration::from_millis(10);

    #[test]
    fn fifo_order_single_thread() {
        let q = RunQueue::new();
        assert_eq!(q.push(1), 1);
        assert_eq!(q.push(2), 2);
        assert_eq!(q.pop(POLL), Some((1, 1)));
        assert_eq!(q.pop(POLL), Some((2, 0)));
        assert!(q.is_empty());
    }

    #[test]
    fn stop_unblocks_and_sticks() {
        let q = Arc::new(RunQueue::<u32>::new());
        let popper = {
            let q = q.clone();
            std::thread::spawn(move || q.pop(POLL))
        };
        q.stop();
        assert_eq!(popper.join().unwrap(), None);
        // Items pushed after stop are never handed out...
        q.push(9);
        assert_eq!(q.pop(POLL), None);
        // ...but an explicit drain recovers them.
        assert_eq!(q.drain(), vec![9]);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // 100-item sleep-poll handoff — too slow under Miri
    fn items_cross_threads() {
        let q = Arc::new(RunQueue::new());
        let consumer = {
            let q = q.clone();
            std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some((item, _)) = q.pop(POLL) {
                    got.push(item);
                }
                got
            })
        };
        for i in 0..100 {
            q.push(i);
        }
        while !q.is_empty() {
            std::thread::sleep(Duration::from_millis(1));
        }
        q.stop();
        let got = consumer.join().unwrap();
        assert_eq!(got, (0..100).collect::<Vec<_>>());
    }
}
