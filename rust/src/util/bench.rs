//! Micro-benchmark harness (criterion replacement for the offline build).
//!
//! JMH-style protocol matching the paper's §4.3 methodology: warmup
//! iterations followed by measurement iterations, reporting mean ± stddev
//! of per-op time. A `black_box` sink prevents the optimizer from deleting
//! the measured work.

use std::hint::black_box as std_black_box;
use std::path::Path;
use std::time::Instant;

use crate::util::Json;

/// Re-export of the optimizer sink.
#[inline(always)]
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// One benchmark's measured statistics.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    /// Mean nanoseconds per operation.
    pub mean_ns: f64,
    /// Standard deviation across measurement iterations.
    pub std_ns: f64,
    pub iterations: usize,
    pub ops_per_iter: u64,
}

impl Measurement {
    pub fn throughput_mops(&self) -> f64 {
        1e3 / self.mean_ns
    }

    /// The measurement as a JSON object (the row shape of `BENCH_*.json`
    /// perf artifacts; callers may append extra fields).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("mean_ns", Json::Num(self.mean_ns)),
            ("std_ns", Json::Num(self.std_ns)),
            ("iterations", Json::Num(self.iterations as f64)),
            ("ops_per_iter", Json::Num(self.ops_per_iter as f64)),
            ("mops", Json::Num(self.throughput_mops())),
        ])
    }
}

/// Write a `BENCH_*.json` perf artifact: `{"bench": ..., "results":
/// [...]}`. Benches emit these so the repo accumulates a throughput
/// trajectory that regressions show up against.
pub fn write_bench_json(path: &Path, bench: &str, results: Vec<Json>) -> std::io::Result<()> {
    let doc = Json::obj(vec![
        ("bench", Json::Str(bench.to_string())),
        ("results", Json::Arr(results)),
    ]);
    let mut text = doc.to_string();
    text.push('\n');
    std::fs::write(path, text)
}

/// Harness configuration (JMH-flavored).
#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub measure_iters: usize,
    /// Target wall time per iteration; op count adapts to reach it.
    pub iter_time_ms: u64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup_iters: 3, measure_iters: 7, iter_time_ms: 200 }
    }
}

/// Quick config for CI/tests.
impl BenchConfig {
    pub fn quick() -> Self {
        BenchConfig { warmup_iters: 1, measure_iters: 3, iter_time_ms: 30 }
    }

    /// Honor `SIMETRA_BENCH_QUICK=1` (used by `cargo test`-driven smoke).
    pub fn from_env() -> Self {
        if std::env::var("SIMETRA_BENCH_QUICK").as_deref() == Ok("1") {
            Self::quick()
        } else {
            Self::default()
        }
    }
}

/// Measure `op`, which must execute `ops_per_call` logical operations per
/// invocation (e.g. a loop over a pre-generated array — the paper's Table 2
/// protocol) and return a value to sink.
pub fn bench<T>(
    config: &BenchConfig,
    name: &str,
    ops_per_call: u64,
    mut op: impl FnMut() -> T,
) -> Measurement {
    // Calibrate: how many calls fit in iter_time_ms?
    let t0 = Instant::now();
    black_box(op());
    let once = t0.elapsed().as_nanos().max(1) as u64;
    let target_ns = config.iter_time_ms * 1_000_000;
    let calls_per_iter = (target_ns / once).clamp(1, 1_000_000_000);

    let run_iter = |op: &mut dyn FnMut() -> T| -> f64 {
        let t = Instant::now();
        for _ in 0..calls_per_iter {
            black_box(op());
        }
        t.elapsed().as_nanos() as f64 / (calls_per_iter * ops_per_call) as f64
    };

    for _ in 0..config.warmup_iters {
        run_iter(&mut op);
    }
    let samples: Vec<f64> = (0..config.measure_iters).map(|_| run_iter(&mut op)).collect();
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>()
        / samples.len().max(1) as f64;
    Measurement {
        name: name.to_string(),
        mean_ns: mean,
        std_ns: var.sqrt(),
        iterations: config.measure_iters,
        ops_per_iter: calls_per_iter * ops_per_call,
    }
}

/// Print a Table-2-style row.
pub fn report(m: &Measurement) {
    println!(
        "{:<24} {:>10.3} ns/op  ± {:>7.3} ns  ({} iters x {} ops)",
        m.name, m.mean_ns, m.std_ns, m.iterations, m.ops_per_iter
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_sane() {
        let cfg = BenchConfig::quick();
        let data: Vec<f64> = (0..1024).map(|i| i as f64).collect();
        let m = bench(&cfg, "sum", data.len() as u64, || {
            data.iter().sum::<f64>()
        });
        assert!(m.mean_ns > 0.0 && m.mean_ns < 1e5, "{}", m.mean_ns);
    }

    #[test]
    fn slower_op_measures_slower() {
        let cfg = BenchConfig::quick();
        let small: Vec<f64> = (0..64).map(|i| i as f64).collect();
        let big: Vec<f64> = (0..65536).map(|i| i as f64).collect();
        let fast = bench(&cfg, "fast", 1, || small.iter().sum::<f64>());
        let slow = bench(&cfg, "slow", 1, || big.iter().sum::<f64>());
        assert!(slow.mean_ns > fast.mean_ns * 10.0);
    }
}
