//! Minimal JSON: parser + writer (in-tree replacement for `serde_json`,
//! unavailable in this offline build).
//!
//! Supports the full JSON grammar except for exotic float forms; numbers
//! are f64 (adequate for the manifest and the wire protocol). Object key
//! order is preserved.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// First integer a JSON double can no longer represent unambiguously
/// (2^53: a sender's 2^53+1 rounds to it). The one source of truth for
/// the wire id range: [`Json::as_u64`] rejects ids at or above it on
/// parse, and the protocol client refuses to serialize them.
pub const MAX_EXACT_JSON_INT: u64 = 1 << 53;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    // --- constructors -----------------------------------------------------
    pub fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(values: impl IntoIterator<Item = f64>) -> Json {
        Json::Arr(values.into_iter().map(Json::Num).collect())
    }

    pub fn arr_f32(values: impl IntoIterator<Item = f32>) -> Json {
        Json::Arr(values.into_iter().map(|v| Json::Num(v as f64)).collect())
    }

    // --- accessors ---------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing field '{key}'"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(v) => Ok(*v),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let v = self.as_f64()?;
        if v < 0.0 || v.fract() != 0.0 {
            bail!("expected non-negative integer, got {v}");
        }
        Ok(v as usize)
    }

    /// Parse a `u64` id directly — NOT via `as_usize` (which would
    /// silently truncate above `usize::MAX` on 32-bit targets). JSON
    /// numbers are f64, so integers above [`MAX_EXACT_JSON_INT`] are not
    /// exactly representable; values **at or above** the boundary are
    /// rejected rather than silently rounded — 2^53 itself is ambiguous,
    /// because a sender's 2^53+1 arrives as exactly 2^53.
    pub fn as_u64(&self) -> Result<u64> {
        let v = self.as_f64()?;
        if v < 0.0 || v.fract() != 0.0 {
            bail!("expected non-negative integer, got {v}");
        }
        if v >= MAX_EXACT_JSON_INT as f64 {
            bail!("integer {v} is not exactly representable in JSON (>= 2^53)");
        }
        Ok(v as u64)
    }

    pub fn as_i64(&self) -> Result<i64> {
        let v = self.as_f64()?;
        if v.fract() != 0.0 {
            bail!("expected integer, got {v}");
        }
        Ok(v as i64)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_f32_vec(&self) -> Result<Vec<f32>> {
        self.as_arr()?.iter().map(|v| Ok(v.as_f64()? as f32)).collect()
    }

    // --- parsing -----------------------------------------------------------
    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut pos = 0;
        let value = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            bail!("trailing characters at offset {pos}");
        }
        Ok(value)
    }

    // --- writing -----------------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(v) => write_num(out, *v),
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Object fields as a map (for tests / diffing).
    pub fn to_map(&self) -> BTreeMap<String, Json> {
        match self {
            Json::Obj(fields) => fields.iter().cloned().collect(),
            _ => BTreeMap::new(),
        }
    }
}

/// Write a JSON number exactly the way [`Json::to_string`] does: values
/// that are integral (and within f64's exact integer range) print without
/// a decimal point, everything else through Rust's shortest-roundtrip
/// float formatting. Shared with the streaming serializer
/// (`coordinator::protocol::write_response`) so the tree-free writer is
/// byte-identical to the tree writer by construction, not by testing luck.
pub fn write_num(out: &mut String, v: f64) {
    if v.fract() == 0.0 && v.abs() < 9e15 {
        let _ = write!(out, "{}", v as i64);
    } else {
        let _ = write!(out, "{v}");
    }
}

/// Write `s` as a quoted, escaped JSON string — the one escape routine
/// both the tree writer and the streaming serializer go through.
pub fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    match b.get(*pos) {
        None => bail!("unexpected end of input"),
        Some(b'n') => parse_lit(b, pos, "null", Json::Null),
        Some(b't') => parse_lit(b, pos, "true", Json::Bool(true)),
        Some(b'f') => parse_lit(b, pos, "false", Json::Bool(false)),
        Some(b'"') => parse_string(b, pos).map(Json::Str),
        Some(b'[') => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    other => bail!("expected ',' or ']' at {pos}, got {other:?}"),
                }
            }
        }
        Some(b'{') => {
            *pos += 1;
            let mut fields = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(fields));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                skip_ws(b, pos);
                if b.get(*pos) != Some(&b':') {
                    bail!("expected ':' at {pos}");
                }
                *pos += 1;
                let value = parse_value(b, pos)?;
                fields.push((key, value));
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(fields));
                    }
                    other => bail!("expected ',' or '}}' at {pos}, got {other:?}"),
                }
            }
        }
        Some(_) => parse_number(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, value: Json) -> Result<Json> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(value)
    } else {
        bail!("invalid literal at {pos}")
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    if b.get(*pos) != Some(&b'"') {
        bail!("expected string at {pos}");
    }
    *pos += 1;
    let mut out = String::new();
    loop {
        match b.get(*pos) {
            None => bail!("unterminated string"),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'u') => {
                        let hex = b
                            .get(*pos + 1..*pos + 5)
                            .ok_or_else(|| anyhow!("bad \\u escape"))?;
                        let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                        // Surrogate pairs: decode when followed by a low
                        // surrogate; lone surrogates map to U+FFFD.
                        if (0xD800..0xDC00).contains(&code)
                            && b.get(*pos + 5..*pos + 7) == Some(b"\\u")
                        {
                            let hex2 = b
                                .get(*pos + 7..*pos + 11)
                                .ok_or_else(|| anyhow!("bad surrogate pair"))?;
                            let low = u32::from_str_radix(std::str::from_utf8(hex2)?, 16)?;
                            if (0xDC00..0xE000).contains(&low) {
                                let c = 0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00);
                                out.push(char::from_u32(c).unwrap_or('\u{FFFD}'));
                                *pos += 10;
                            } else {
                                // Mismatched pair: the high surrogate is
                                // lone (U+FFFD) and the second escape is
                                // re-scanned on its own — `low - 0xDC00`
                                // would underflow here.
                                out.push('\u{FFFD}');
                                *pos += 4;
                            }
                        } else {
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            *pos += 4;
                        }
                    }
                    other => bail!("bad escape {other:?}"),
                }
                *pos += 1;
            }
            Some(_) => {
                // Consume one UTF-8 scalar.
                let rest = std::str::from_utf8(&b[*pos..])?;
                let c = rest.chars().next().unwrap();
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let text = std::str::from_utf8(&b[start..*pos])?;
    let v: f64 = text.parse().map_err(|e| anyhow!("bad number '{text}': {e}"))?;
    Ok(Json::Num(v))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_structures() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": true, "d": null}, "e": "x\ny"}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_bool().unwrap(), true);
        assert_eq!(v.get("e").unwrap().as_str().unwrap(), "x\ny");
        let text = v.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("42").unwrap().as_i64().unwrap(), 42);
        assert_eq!(Json::parse("-1.5").unwrap().as_f64().unwrap(), -1.5);
        assert_eq!(Json::parse("1e3").unwrap().as_f64().unwrap(), 1000.0);
        assert!(Json::parse("1.5").unwrap().as_usize().is_err());
    }

    #[test]
    fn escapes_round_trip() {
        let v = Json::Str("quote\" slash\\ tab\t nl\n".into());
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""é""#).unwrap().as_str().unwrap(), "é");
        assert_eq!(Json::parse(r#""😀""#).unwrap().as_str().unwrap(), "😀");
    }

    #[test]
    fn surrogate_escapes_decode_or_degrade_to_replacement() {
        assert_eq!(Json::parse(r#""😀""#).unwrap().as_str().unwrap(), "😀");
        // Lone and mismatched surrogates decode to U+FFFD instead of
        // underflowing `low - 0xDC00`.
        assert_eq!(Json::parse(r#""\ud800""#).unwrap().as_str().unwrap(), "\u{FFFD}");
        assert_eq!(Json::parse(r#""\udc00""#).unwrap().as_str().unwrap(), "\u{FFFD}");
        assert_eq!(Json::parse(r#""\ud800A""#).unwrap().as_str().unwrap(), "\u{FFFD}A");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn f32_vec_helper() {
        let v = Json::parse("[0.5, 1, -2]").unwrap().as_f32_vec().unwrap();
        assert_eq!(v, vec![0.5f32, 1.0, -2.0]);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(vec![]));
        assert_eq!(Json::Arr(vec![]).to_string(), "[]");
    }
}
