//! Allocation-free streaming JSON pull-parser over a borrowed byte slice.
//!
//! The wire hot path (`coordinator::protocol::parse_wire_streaming`) walks
//! request lines with this parser instead of materializing a `Json` tree:
//! no recursion (an explicit bitstack tracks container nesting, bounded by
//! [`MAX_DEPTH`]), no heap traffic (string events are borrowed
//! [`StrSpan`]s; escape decoding goes into caller-provided scratch), one
//! event at a time off the socket buffer — the picojson idiom.
//!
//! Conformance contract: this parser accepts exactly the documents
//! [`crate::util::Json::parse`] accepts (including its quirks — the
//! permissive number scan that admits `1e999` as `inf` and a leading `+`,
//! and the U+FFFD policy for lone or mismatched surrogate escapes), and
//! decodes strings to identical contents. The tree parser stays in the
//! codebase as the differential oracle (`tests/integration_wire.rs`).

use std::fmt;

/// Maximum container nesting the pull-parser accepts. One bit of the
/// nesting stack per level; wire requests are at most 3 deep, so 64 is
/// pure headroom — but unlike the recursive tree parser, a hostile
/// deeply-nested line errors here instead of growing the thread stack.
pub const MAX_DEPTH: u32 = 64;

/// A parse error: a static message plus the byte offset it refers to.
/// Construction never allocates (the hot path stays zero-alloc even when
/// rejecting garbage).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamError {
    pub msg: &'static str,
    pub at: usize,
}

impl fmt::Display for StreamError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} at offset {}", self.msg, self.at)
    }
}

impl std::error::Error for StreamError {}

/// The raw content of a JSON string (the bytes between the quotes, escape
/// sequences unprocessed), borrowed from the input line.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StrSpan<'a> {
    bytes: &'a [u8],
    escaped: bool,
    at: usize,
}

impl<'a> StrSpan<'a> {
    /// The raw bytes between the quotes (escapes unprocessed).
    pub fn raw(&self) -> &'a [u8] {
        self.bytes
    }

    /// Whether the span contains at least one `\` escape (i.e. whether
    /// [`StrSpan::decode`] needs the scratch buffer).
    pub fn is_escaped(&self) -> bool {
        self.escaped
    }

    /// Decode the string content. Escape-free spans are returned as a
    /// borrow of the input line; spans with escapes are decoded into
    /// `scratch` (cleared first) — either way no allocation happens once
    /// the scratch has warmed to the longest escaped string seen.
    pub fn decode<'s>(&self, scratch: &'s mut String) -> Result<&'s str, StreamError>
    where
        'a: 's,
    {
        if !self.escaped {
            return std::str::from_utf8(self.bytes)
                .map_err(|_| StreamError { msg: "invalid UTF-8 in string", at: self.at });
        }
        scratch.clear();
        decode_escaped(self.bytes, self.at, scratch)?;
        Ok(scratch.as_str())
    }

    /// Whether the decoded content equals `expected` (key matching on the
    /// hot path: escape-free spans compare without touching the scratch).
    pub fn eq_decoded(&self, expected: &str, scratch: &mut String) -> bool {
        if !self.escaped {
            return self.bytes == expected.as_bytes();
        }
        matches!(self.decode(scratch), Ok(s) if s == expected)
    }
}

/// Decode a string body that contains at least one escape into `out`,
/// mirroring the tree parser's `parse_string` exactly: the same escape
/// set, the same `\u` hex parse, and the same U+FFFD policy for lone or
/// mismatched surrogates.
fn decode_escaped(b: &[u8], base: usize, out: &mut String) -> Result<(), StreamError> {
    let bad = |at: usize, msg: &'static str| StreamError { msg, at };
    let mut pos = 0;
    while pos < b.len() {
        if b[pos] != b'\\' {
            let start = pos;
            while pos < b.len() && b[pos] != b'\\' {
                pos += 1;
            }
            let chunk = std::str::from_utf8(&b[start..pos])
                .map_err(|_| bad(base + start, "invalid UTF-8 in string"))?;
            out.push_str(chunk);
            continue;
        }
        pos += 1;
        match b.get(pos) {
            Some(b'"') => out.push('"'),
            Some(b'\\') => out.push('\\'),
            Some(b'/') => out.push('/'),
            Some(b'n') => out.push('\n'),
            Some(b't') => out.push('\t'),
            Some(b'r') => out.push('\r'),
            Some(b'b') => out.push('\u{0008}'),
            Some(b'f') => out.push('\u{000C}'),
            Some(b'u') => {
                let hex = b.get(pos + 1..pos + 5).ok_or(bad(base + pos, "bad \\u escape"))?;
                let code = parse_hex4(hex, base + pos)?;
                // Surrogate pairs: a high surrogate combines with the low
                // surrogate escape that follows; a lone high surrogate, or
                // one followed by a non-low-surrogate escape, decodes to
                // U+FFFD and the next escape is re-scanned on its own.
                if (0xD800..0xDC00).contains(&code) && b.get(pos + 5..pos + 7) == Some(b"\\u") {
                    let hex2 =
                        b.get(pos + 7..pos + 11).ok_or(bad(base + pos, "bad surrogate pair"))?;
                    let low = parse_hex4(hex2, base + pos + 6)?;
                    if (0xDC00..0xE000).contains(&low) {
                        let c = 0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00);
                        out.push(char::from_u32(c).unwrap_or('\u{FFFD}'));
                        pos += 10;
                    } else {
                        out.push('\u{FFFD}');
                        pos += 4;
                    }
                } else {
                    out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    pos += 4;
                }
            }
            _ => return Err(bad(base + pos, "bad escape")),
        }
        pos += 1;
    }
    Ok(())
}

/// Parse one `\u` hex quartet. `u32::from_str_radix` is the same routine
/// the tree parser uses — it accepts a leading `+` (so `\u+12f` parses),
/// and conformance means preserving that quirk.
fn parse_hex4(hex: &[u8], at: usize) -> Result<u32, StreamError> {
    std::str::from_utf8(hex)
        .ok()
        .and_then(|s| u32::from_str_radix(s, 16).ok())
        .ok_or(StreamError { msg: "bad \\u escape", at })
}

/// One parse event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Event<'a> {
    ObjBegin,
    ObjEnd,
    ArrBegin,
    ArrEnd,
    /// An object key; the `:` after it is already consumed, so the next
    /// event is the key's value.
    Key(StrSpan<'a>),
    Str(StrSpan<'a>),
    Num(f64),
    Bool(bool),
    Null,
    /// End of input, emitted once the top-level value has closed and only
    /// trailing whitespace remains (anything else is an error, matching
    /// the tree parser's trailing-characters check).
    End,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Expect {
    Value,
    ValueOrArrEnd,
    Key,
    KeyOrObjEnd,
    CommaOrClose,
    Done,
}

/// The pull-parser: an explicit-state event iterator over one request
/// line. No recursion — container nesting lives in a 64-bit stack (one
/// bit per level, 1 = object, 0 = array).
pub struct PullParser<'a> {
    b: &'a [u8],
    pos: usize,
    stack: u64,
    depth: u32,
    expect: Expect,
}

impl<'a> PullParser<'a> {
    pub fn new(line: &'a [u8]) -> PullParser<'a> {
        PullParser { b: line, pos: 0, stack: 0, depth: 0, expect: Expect::Value }
    }

    /// Byte offset of the next unconsumed input (error reporting).
    pub fn offset(&self) -> usize {
        self.pos
    }

    fn err(&self, msg: &'static str) -> StreamError {
        StreamError { msg, at: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    /// Pull the next event.
    #[allow(clippy::should_implement_trait)] // fallible, not an Iterator
    pub fn next(&mut self) -> Result<Event<'a>, StreamError> {
        loop {
            self.skip_ws();
            match self.expect {
                Expect::Done => {
                    return if self.pos == self.b.len() {
                        Ok(Event::End)
                    } else {
                        Err(self.err("trailing characters"))
                    };
                }
                Expect::Key | Expect::KeyOrObjEnd => {
                    if self.expect == Expect::KeyOrObjEnd && self.peek() == Some(b'}') {
                        self.pos += 1;
                        return Ok(self.pop(Event::ObjEnd));
                    }
                    let span = self.string()?;
                    self.skip_ws();
                    if self.peek() != Some(b':') {
                        return Err(self.err("expected ':'"));
                    }
                    self.pos += 1;
                    self.expect = Expect::Value;
                    return Ok(Event::Key(span));
                }
                Expect::Value | Expect::ValueOrArrEnd => {
                    if self.expect == Expect::ValueOrArrEnd && self.peek() == Some(b']') {
                        self.pos += 1;
                        return Ok(self.pop(Event::ArrEnd));
                    }
                    return self.value();
                }
                Expect::CommaOrClose => {
                    let in_obj = self.stack & 1 == 1;
                    match self.peek() {
                        Some(b',') => {
                            self.pos += 1;
                            self.expect = if in_obj { Expect::Key } else { Expect::Value };
                        }
                        Some(b'}') if in_obj => {
                            self.pos += 1;
                            return Ok(self.pop(Event::ObjEnd));
                        }
                        Some(b']') if !in_obj => {
                            self.pos += 1;
                            return Ok(self.pop(Event::ArrEnd));
                        }
                        _ => {
                            return Err(self.err(if in_obj {
                                "expected ',' or '}'"
                            } else {
                                "expected ',' or ']'"
                            }));
                        }
                    }
                }
            }
        }
    }

    /// Consume one complete value; the parser must be at a value boundary.
    pub fn skip_value(&mut self) -> Result<(), StreamError> {
        let first = self.next()?;
        self.finish_value(first)
    }

    /// Consume the remainder of a value whose first event was already
    /// pulled (a no-op for scalars).
    pub fn finish_value(&mut self, first: Event<'a>) -> Result<(), StreamError> {
        let mut open = match first {
            Event::ObjBegin | Event::ArrBegin => 1u32,
            Event::End => return Err(self.err("unexpected end of input")),
            _ => return Ok(()),
        };
        while open > 0 {
            match self.next()? {
                Event::ObjBegin | Event::ArrBegin => open += 1,
                Event::ObjEnd | Event::ArrEnd => open -= 1,
                Event::End => return Err(self.err("unexpected end of input")),
                _ => {}
            }
        }
        Ok(())
    }

    fn value(&mut self) -> Result<Event<'a>, StreamError> {
        match self.peek() {
            None => Err(self.err("unexpected end of input")),
            Some(b'n') => {
                self.literal("null")?;
                self.after_value();
                Ok(Event::Null)
            }
            Some(b't') => {
                self.literal("true")?;
                self.after_value();
                Ok(Event::Bool(true))
            }
            Some(b'f') => {
                self.literal("false")?;
                self.after_value();
                Ok(Event::Bool(false))
            }
            Some(b'"') => {
                let span = self.string()?;
                self.after_value();
                Ok(Event::Str(span))
            }
            Some(b'[') => {
                self.push(false)?;
                Ok(Event::ArrBegin)
            }
            Some(b'{') => {
                self.push(true)?;
                Ok(Event::ObjBegin)
            }
            // Anything else is attempted as a number — the tree parser's
            // dispatch, so garbage rejects identically.
            Some(_) => {
                let n = self.number()?;
                self.after_value();
                Ok(Event::Num(n))
            }
        }
    }

    fn after_value(&mut self) {
        self.expect = if self.depth == 0 { Expect::Done } else { Expect::CommaOrClose };
    }

    fn push(&mut self, is_obj: bool) -> Result<(), StreamError> {
        if self.depth == MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        self.stack = (self.stack << 1) | u64::from(is_obj);
        self.depth += 1;
        self.pos += 1;
        self.expect = if is_obj { Expect::KeyOrObjEnd } else { Expect::ValueOrArrEnd };
        Ok(())
    }

    fn pop(&mut self, ev: Event<'a>) -> Event<'a> {
        self.stack >>= 1;
        self.depth -= 1;
        self.after_value();
        ev
    }

    /// Scan a string, validating escapes and UTF-8 without decoding.
    fn string(&mut self) -> Result<StrSpan<'a>, StreamError> {
        if self.peek() != Some(b'"') {
            return Err(self.err("expected string"));
        }
        self.pos += 1;
        let start = self.pos;
        let mut escaped = false;
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    let span = StrSpan { bytes: &self.b[start..self.pos], escaped, at: start };
                    self.pos += 1;
                    return Ok(span);
                }
                Some(b'\\') => {
                    escaped = true;
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"' | b'\\' | b'/' | b'n' | b't' | b'r' | b'b' | b'f') => {
                            self.pos += 1;
                        }
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            parse_hex4(hex, self.pos)?;
                            self.pos += 5;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                Some(_) => {
                    // Raw run up to the next quote/escape; a multi-byte
                    // UTF-8 scalar never contains 0x22 or 0x5C, so the
                    // break bytes cannot split a valid sequence.
                    let run = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    if std::str::from_utf8(&self.b[run..self.pos]).is_err() {
                        return Err(StreamError { msg: "invalid UTF-8 in string", at: run });
                    }
                }
            }
        }
    }

    /// Number scan: the same byte set and `f64` parse as the tree parser
    /// (`1e999` parses to `inf`; a bare `NaN` already fails at the scan).
    fn number(&mut self) -> Result<f64, StreamError> {
        let start = self.pos;
        while matches!(self.peek(), Some(b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')) {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|t| t.parse::<f64>().ok())
            .ok_or(StreamError { msg: "bad number", at: start })
    }

    fn literal(&mut self, lit: &'static str) -> Result<(), StreamError> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(())
        } else {
            Err(self.err("invalid literal"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Json;

    /// Validate a whole document the way the wire path does: first event,
    /// finish the value, then require a clean end.
    fn scan(src: &str) -> Result<(), StreamError> {
        let mut p = PullParser::new(src.as_bytes());
        let first = p.next()?;
        p.finish_value(first)?;
        match p.next()? {
            Event::End => Ok(()),
            other => panic!("expected End, got {other:?}"),
        }
    }

    #[test]
    fn accepts_and_rejects_exactly_like_the_tree_parser() {
        let cases = [
            r#"{"a": [1, 2.5, -3e2], "b": {"c": true, "d": null}, "e": "x\ny"}"#,
            "42",
            "-1.5",
            "1e3",
            "1e999",
            "+5",
            "[]",
            "{}",
            "null",
            "true",
            "false",
            "  [ 1 , 2 ]  ",
            r#""😀""#,
            r#""\ud800""#,
            r#""\ud800A""#,
            r#"{"op":"knn","k":1}"#,
            // Rejections (every one must reject in BOTH parsers).
            "[1,]",
            "{\"a\" 1}",
            "{\"a\":1,}",
            r#"{"a":}"#,
            "tru",
            "1 2",
            "{",
            "[1",
            "\"unterminated",
            r#""\q""#,
            "nan",
            "NaN",
            "{}x",
            "",
            "[1 2]",
            r#"{"a":1 "b":2}"#,
            r#"{1: 2}"#,
        ];
        for src in cases {
            let tree = Json::parse(src).is_ok();
            let stream = scan(src).is_ok();
            assert_eq!(stream, tree, "accept/reject divergence on {src:?}");
        }
    }

    #[test]
    fn event_sequence_walks_nested_documents() {
        let src = r#"{"op":"knn","vector":[1,2.5],"deep":{"x":[true,null]}}"#;
        let mut p = PullParser::new(src.as_bytes());
        let mut scratch = String::new();
        assert_eq!(p.next().unwrap(), Event::ObjBegin);
        match p.next().unwrap() {
            Event::Key(k) => assert!(k.eq_decoded("op", &mut scratch)),
            other => panic!("{other:?}"),
        }
        match p.next().unwrap() {
            Event::Str(s) => assert_eq!(s.decode(&mut scratch).unwrap(), "knn"),
            other => panic!("{other:?}"),
        }
        match p.next().unwrap() {
            Event::Key(k) => assert!(k.eq_decoded("vector", &mut scratch)),
            other => panic!("{other:?}"),
        }
        assert_eq!(p.next().unwrap(), Event::ArrBegin);
        assert_eq!(p.next().unwrap(), Event::Num(1.0));
        assert_eq!(p.next().unwrap(), Event::Num(2.5));
        assert_eq!(p.next().unwrap(), Event::ArrEnd);
        match p.next().unwrap() {
            Event::Key(k) => assert!(k.eq_decoded("deep", &mut scratch)),
            other => panic!("{other:?}"),
        }
        p.skip_value().unwrap();
        assert_eq!(p.next().unwrap(), Event::ObjEnd);
        assert_eq!(p.next().unwrap(), Event::End);
    }

    #[test]
    fn string_decode_matches_the_tree_parser() {
        let cases = [
            r#""plain""#,
            r#""q\" s\\ t\t n\n r\r b\b f\f sl\/""#,
            "\"\u{e9} \u{0} \u{ffff}\"",
            r#""😀""#,
            r#""\ud800x""#,
            r#""\udc00""#,
            r#""\ud800A""#,
            r#""mix é 😀""#,
        ];
        for src in cases {
            let want = Json::parse(src).unwrap();
            let mut p = PullParser::new(src.as_bytes());
            let span = match p.next().unwrap() {
                Event::Str(s) => s,
                other => panic!("{other:?}"),
            };
            let mut scratch = String::new();
            assert_eq!(span.decode(&mut scratch).unwrap(), want.as_str().unwrap(), "{src}");
        }
    }

    #[test]
    fn numbers_parse_to_identical_bits() {
        for src in ["0", "-0.0", "1e999", "-1e999", "3.141592653589793", "9007199254740993"] {
            let tree = Json::parse(src).unwrap().as_f64().unwrap();
            let mut p = PullParser::new(src.as_bytes());
            match p.next().unwrap() {
                Event::Num(n) => assert_eq!(n.to_bits(), tree.to_bits(), "{src}"),
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn nesting_depth_is_bounded() {
        let ok = format!("{}{}", "[".repeat(MAX_DEPTH as usize), "]".repeat(MAX_DEPTH as usize));
        assert!(scan(&ok).is_ok());
        let deep = format!(
            "{}{}",
            "[".repeat(MAX_DEPTH as usize + 1),
            "]".repeat(MAX_DEPTH as usize + 1)
        );
        assert_eq!(scan(&deep).unwrap_err().msg, "nesting too deep");
    }

    #[test]
    fn errors_carry_offsets_without_allocating() {
        let err = scan(r#"{"a": zz}"#).unwrap_err();
        assert_eq!(err.msg, "bad number");
        assert_eq!(err.at, 6);
        assert_eq!(err.to_string(), "bad number at offset 6");
    }
}
