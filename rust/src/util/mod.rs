//! In-tree infrastructure for the offline build: RNG, JSON, micro-bench
//! harness (replacing `rand`, `serde_json`, `criterion`).

pub mod bench;
pub mod json;
pub mod json_stream;
pub mod rng;

pub use json::Json;
pub use rng::Rng;
