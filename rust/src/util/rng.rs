//! Deterministic PRNG (xoshiro256++) and distributions.
//!
//! In-tree replacement for the `rand`/`rand_distr` crates (unavailable in
//! this offline build): seeding via splitmix64, uniform and Gaussian
//! (Box–Muller) sampling. Not cryptographic; statistical quality is ample
//! for workload generation.

/// xoshiro256++ PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller sample.
    gauss_cache: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically from a single u64.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut st = seed;
        Rng {
            s: [
                splitmix64(&mut st),
                splitmix64(&mut st),
                splitmix64(&mut st),
                splitmix64(&mut st),
            ],
            gauss_cache: None,
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` (n > 0), bias-free via rejection.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.gauss_cache.take() {
            return v;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.gauss_cache = Some(r * s);
            return r * c;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, data: &mut [T]) {
        for i in (1..data.len()).rev() {
            data.swap(i, self.below(i + 1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_seed() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(1);
        let mut c = Rng::seed_from_u64(2);
        let va: Vec<u64> = (0..10).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..10).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..10).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut rng = Rng::seed_from_u64(3);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = rng.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::seed_from_u64(4);
        let n = 100_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let v = rng.normal();
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Rng::seed_from_u64(5);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[rng.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::seed_from_u64(6);
        let mut v: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // overwhelmingly likely
    }
}
