//! Public-API surface snapshot (ADR-005 satellite): the compat layer the
//! search redesign promised must keep existing. This file is a
//! compile-time contract — if a future refactor drops or re-types one of
//! the legacy shim signatures (`knn` / `knn_into` / `range` / `range_into`
//! / `knn_batch` / `range_batch`, the layer-level `*_ctx` pairs, or the
//! wire ops), this test stops compiling instead of silently breaking
//! downstream users. Paired with the CI `cargo doc` warnings-as-errors
//! step, which catches broken intra-doc links to renamed items.

use simetra::bounds::BoundKind;
use simetra::coordinator::{Coordinator, CoordinatorConfig, Hit, Request, SearchResult, Shard};
use simetra::data::uniform_sphere;
use simetra::error::SimetraError;
use simetra::index::{LinearScan, QueryStats, SimilarityIndex, VpTree};
use simetra::ingest::IngestCorpus;
use simetra::metrics::DenseVec;
use simetra::query::{QueryContext, SearchRequest, SearchResponse};

/// The full legacy `SimilarityIndex` shim surface, exercised generically:
/// any index must expose every pre-redesign entry point as a provided
/// method over `search_into`.
fn legacy_index_surface<I: SimilarityIndex<DenseVec> + ?Sized>(index: &I, q: &DenseVec) {
    let mut stats = QueryStats::default();
    let _hits: Vec<(u32, f64)> = index.knn(q, 3, &mut stats);
    let _hits: Vec<(u32, f64)> = index.range(q, 0.5, &mut stats);

    let mut ctx = QueryContext::new();
    let mut out: Vec<(u32, f64)> = Vec::new();
    ctx.begin_query();
    index.knn_into(q, 3, &mut ctx, &mut out);
    ctx.begin_query();
    index.range_into(q, 0.5, &mut ctx, &mut out);

    let queries = vec![q.clone()];
    let _batch: Vec<(Vec<(u32, f64)>, QueryStats)> = index.knn_batch(&queries, 3, &mut ctx);
    let _batch: Vec<(Vec<(u32, f64)>, QueryStats)> = index.range_batch(&queries, 0.5, &mut ctx);

    // And the one required entry point itself.
    let mut resp = SearchResponse::default();
    ctx.begin_query();
    index.search_into(q, &SearchRequest::knn(3).build(), &mut ctx, &mut resp);
    let _resp: SearchResponse = index.search(q, &SearchRequest::range(0.5).build());

    let _n: usize = index.len();
    let _name: &'static str = index.name();
}

#[test]
fn similarity_index_legacy_shims_still_exist() {
    let pts = uniform_sphere(64, 8, 1);
    let q = pts[0].clone();
    legacy_index_surface(&LinearScan::build(pts.clone()), &q);
    legacy_index_surface(&VpTree::build(pts.clone(), BoundKind::Mult, 1), &q);
    // Trait-object form (the coordinator's shape) keeps working too.
    let boxed: Box<dyn SimilarityIndex<DenseVec>> = Box::new(LinearScan::build(pts));
    legacy_index_surface(boxed.as_ref(), &q);
}

#[test]
fn coordinator_and_shard_surfaces_are_stable() {
    // Signature pins (compile-time): the request-path methods and their
    // typed error, plus the shard-level pair of shims.
    let _: fn(&Coordinator, Vec<f32>, usize) -> Result<(Vec<Hit>, u64), SimetraError> =
        Coordinator::knn;
    let _: fn(&Coordinator, Vec<f32>, f64) -> Result<(Vec<Hit>, u64), SimetraError> =
        Coordinator::range;
    let _: fn(&Coordinator, Vec<f32>, SearchRequest) -> Result<SearchResult, SimetraError> =
        Coordinator::search;
    let _: fn(&Coordinator, Vec<f32>) -> Result<u64, SimetraError> = Coordinator::insert;
    let _: fn(&Coordinator, u64) -> Result<bool, SimetraError> = Coordinator::delete;

    let _: fn(&Shard, &DenseVec, usize, &mut QueryContext) -> (Vec<(u32, f64)>, QueryStats) =
        Shard::knn_ctx;
    let _: fn(&Shard, &DenseVec, f64, &mut QueryContext) -> (Vec<(u32, f64)>, QueryStats) =
        Shard::range_ctx;

    let _: fn(&IngestCorpus, &DenseVec, usize) -> (Vec<(u64, f64)>, u64) = IngestCorpus::knn;
    let _: fn(&IngestCorpus, &DenseVec, f64) -> (Vec<(u64, f64)>, u64) = IngestCorpus::range;
}

#[test]
fn wire_ops_are_stable() {
    // The legacy wire ops and the versioned search op all keep parsing.
    let lines = [
        r#"{"op": "knn", "vector": [1.0], "k": 3}"#,
        r#"{"op": "range", "vector": [1.0], "tau": 0.5}"#,
        r#"{"op": "search", "v": 1, "vector": [1.0], "mode": "knn", "k": 3}"#,
        r#"{"op": "insert", "vector": [1.0]}"#,
        r#"{"op": "delete", "id": 7}"#,
        r#"{"op": "flush"}"#,
        r#"{"op": "compact"}"#,
        r#"{"op": "stats"}"#,
        r#"{"op": "config"}"#,
        r#"{"op": "ping"}"#,
    ];
    for line in lines {
        assert!(Request::parse(line).is_ok(), "{line}");
    }
    let _ = CoordinatorConfig::default();
}
