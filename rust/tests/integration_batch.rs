//! ADR-006 batched multi-query traversal: `search_batch_into` (and the
//! shard / ingest layers above it) must match sequential per-query
//! execution bitwise on tie-free corpora, across all 7 indexes × 3
//! kernels × static, sharded, and mutable corpora — while the shared
//! frontier demonstrably does *less* physical work than q independent
//! traversals.

use simetra::bounds::BoundKind;
use simetra::coordinator::router::build_shards;
use simetra::coordinator::IndexKind;
use simetra::data::{uniform_sphere, uniform_sphere_store};
use simetra::index::{QueryStats, SimilarityIndex};
use simetra::ingest::{IngestConfig, IngestCorpus};
use simetra::metrics::DenseVec;
use simetra::query::{QueryContext, SearchRequest, SearchResponse};
use simetra::storage::{CorpusStore, KernelKind};

const ALL_KINDS: [IndexKind; 7] = [
    IndexKind::Linear,
    IndexKind::Vp,
    IndexKind::Ball,
    IndexKind::MTree,
    IndexKind::Cover,
    IndexKind::Laesa,
    IndexKind::Gnat,
];

const ALL_KERNELS: [KernelKind; 3] =
    [KernelKind::Scalar, KernelKind::Simd, KernelKind::QuantizedI8];

/// Bitwise equality of two result lists: same ids, same f64 bit patterns.
fn assert_bits_eq(a: &[(u32, f64)], b: &[(u32, f64)], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: lengths differ");
    for (pos, ((ia, sa), (ib, sb))) in a.iter().zip(b).enumerate() {
        assert_eq!(ia, ib, "{what}: id at {pos}");
        assert_eq!(sa.to_bits(), sb.to_bits(), "{what}: sim bits at {pos}");
    }
}

fn assert_bits_eq64(a: &[(u64, f64)], b: &[(u64, f64)], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: lengths differ");
    for (pos, ((ia, sa), (ib, sb))) in a.iter().zip(b).enumerate() {
        assert_eq!(ia, ib, "{what}: id at {pos}");
        assert_eq!(sa.to_bits(), sb.to_bits(), "{what}: sim bits at {pos}");
    }
}

/// The sequential oracle: one `search_into` per query through a fresh
/// context, exactly what the batch path claims to reproduce.
fn sequential(
    index: &dyn SimilarityIndex<DenseVec>,
    queries: &[DenseVec],
    reqs: &[SearchRequest],
) -> Vec<SearchResponse> {
    let mut ctx = QueryContext::new();
    let mut resps = Vec::new();
    for (q, req) in queries.iter().zip(reqs) {
        ctx.begin_query();
        let mut resp = SearchResponse::default();
        index.search_into(q, req, &mut ctx, &mut resp);
        resps.push(resp);
    }
    resps
}

fn assert_batch_matches(
    index: &dyn SimilarityIndex<DenseVec>,
    queries: &[DenseVec],
    reqs: &[SearchRequest],
    what: &str,
) {
    let mut ctx = QueryContext::new();
    let mut resps = Vec::new();
    index.search_batch_into(queries, reqs, &mut ctx, &mut resps);
    let want = sequential(index, queries, reqs);
    assert_eq!(resps.len(), want.len(), "{what}: response count");
    for (qi, (b, s)) in resps.iter().zip(&want).enumerate() {
        assert_bits_eq(&s.hits, &b.hits, &format!("{what} q{qi}"));
        assert_eq!(s.truncated, b.truncated, "{what} q{qi} truncated");
    }
}

// --- 1. plain batches, all indexes × kernels -------------------------------

#[test]
fn plain_batches_match_sequential_across_indexes_and_kernels() {
    // Corpus size stays >= QUANT_MIN_ROWS so the i8 leg really builds a
    // sidecar and takes the pre-filter + re-rank path.
    let rows = uniform_sphere(1200, 16, 42);
    let queries: Vec<DenseVec> = uniform_sphere(12, 16, 43);
    let knn_reqs: Vec<SearchRequest> =
        (0..queries.len()).map(|_| SearchRequest::knn(8).build()).collect();
    let rng_reqs: Vec<SearchRequest> =
        (0..queries.len()).map(|_| SearchRequest::range(0.15).build()).collect();
    for kernel in ALL_KERNELS {
        let store = CorpusStore::from_rows(rows.clone()).with_kernel(kernel);
        for kind in ALL_KINDS {
            let index = kind.build(store.view(), BoundKind::Mult);
            let what = format!("{} / {}", kind.name(), kernel.name());
            assert_batch_matches(index.as_ref(), &queries, &knn_reqs, &format!("{what} knn"));
            assert_batch_matches(index.as_ref(), &queries, &rng_reqs, &format!("{what} range"));
        }
    }
}

// --- 1b. uniform bound overrides stay on the batched path -------------------

#[test]
fn uniform_bound_override_batches_match_sequential() {
    let rows = uniform_sphere(900, 12, 61);
    let store = CorpusStore::from_rows(rows);
    let queries: Vec<DenseVec> = uniform_sphere(8, 12, 62);
    // Every request overrides the build-time bound with the same kind: the
    // batch must be admitted to the shared traversal (not the per-query
    // fallback) and still match sequential execution bitwise. Auto rides
    // along — it resolves once per chunk, and every resolution is exact.
    for bound in [
        BoundKind::ArccosFast,
        BoundKind::MultLb1,
        BoundKind::Ptolemaic,
        BoundKind::PtolemaicFast,
        BoundKind::Auto,
    ] {
        let knn_reqs: Vec<SearchRequest> =
            (0..queries.len()).map(|_| SearchRequest::knn(6).bound(bound).build()).collect();
        let rng_reqs: Vec<SearchRequest> =
            (0..queries.len()).map(|_| SearchRequest::range(0.1).bound(bound).build()).collect();
        assert!(knn_reqs.iter().all(|r| !r.is_plain() && r.is_plain_except_bound()));
        for kind in ALL_KINDS {
            let index = kind.build(store.view(), BoundKind::Mult);
            let what = format!("{} / {bound:?}", kind.name());
            assert_batch_matches(index.as_ref(), &queries, &knn_reqs, &format!("{what} knn"));
            assert_batch_matches(index.as_ref(), &queries, &rng_reqs, &format!("{what} range"));
        }
    }
}

#[test]
fn mixed_bound_batches_fall_back_and_match_sequential() {
    let store = uniform_sphere_store(700, 10, 63);
    let queries: Vec<DenseVec> = uniform_sphere(6, 10, 64);
    // Disagreeing overrides (and override-vs-none mixes) are not uniform:
    // the batch frame must take the per-query fallback and still be exact.
    let reqs: Vec<SearchRequest> = (0..queries.len())
        .map(|i| match i % 3 {
            0 => SearchRequest::knn(5).bound(BoundKind::Ptolemaic).build(),
            1 => SearchRequest::knn(5).bound(BoundKind::ArccosFast).build(),
            _ => SearchRequest::knn(5).build(),
        })
        .collect();
    for kind in ALL_KINDS {
        let index = kind.build(store.view(), BoundKind::Mult);
        assert_batch_matches(
            index.as_ref(),
            &queries,
            &reqs,
            &format!("mixed-bound {}", kind.name()),
        );
    }
}

// --- 2. mixed modes and ks in one batch ------------------------------------

#[test]
fn mixed_mode_batches_match_sequential() {
    let store = uniform_sphere_store(1000, 12, 7);
    let queries: Vec<DenseVec> = uniform_sphere(9, 12, 8);
    // One batch mixing kNN (varying k), range (varying tau), and
    // KnnWithin slots — every slot keeps its own collector and floor.
    let reqs: Vec<SearchRequest> = (0..queries.len())
        .map(|i| match i % 3 {
            0 => SearchRequest::knn(1 + i).build(),
            1 => SearchRequest::range(0.05 * i as f64).build(),
            _ => SearchRequest::knn_within(5, 0.0).build(),
        })
        .collect();
    for kind in ALL_KINDS {
        let index = kind.build(store.view(), BoundKind::Mult);
        assert_batch_matches(index.as_ref(), &queries, &reqs, &format!("mixed {}", kind.name()));
    }
}

// --- 3. mid-batch retirement ------------------------------------------------

#[test]
fn retiring_slots_leave_live_slots_exact() {
    let store = uniform_sphere_store(1500, 10, 15);
    let queries: Vec<DenseVec> = uniform_sphere(4, 10, 16);
    // Slot 0 retires almost immediately (k=1 with a high floor); slot 3
    // keeps every node alive to the end (tau=-1 admits the whole corpus).
    // The survivors must be exactly what sequential execution returns.
    let reqs = vec![
        SearchRequest::knn_within(1, 0.6).build(),
        SearchRequest::knn(5).build(),
        SearchRequest::range(0.3).build(),
        SearchRequest::range(-1.0).build(),
    ];
    for kind in ALL_KINDS {
        let index = kind.build(store.view(), BoundKind::Mult);
        let what = format!("retire {}", kind.name());
        let mut ctx = QueryContext::new();
        let mut resps = Vec::new();
        index.search_batch_into(&queries, &reqs, &mut ctx, &mut resps);
        assert_eq!(resps[3].hits.len(), 1500, "{what}: tau=-1 returns the whole corpus");
        let want = sequential(index.as_ref(), &queries, &reqs);
        for (qi, (b, s)) in resps.iter().zip(&want).enumerate() {
            assert_bits_eq(&s.hits, &b.hits, &format!("{what} q{qi}"));
        }
    }
}

// --- 4. the shared frontier does less physical work -------------------------

#[test]
fn shared_traversal_visits_fewer_nodes_than_sequential() {
    let store = uniform_sphere_store(2000, 16, 11);
    // 16 identical queries: the shared traversal degenerates to ONE
    // single-query descent (every slot admits and retires the same
    // nodes), so batched nodes_visited must be ~16x below sequential.
    let q = uniform_sphere(1, 16, 12).pop().unwrap();
    let queries: Vec<DenseVec> = vec![q; 16];
    let reqs: Vec<SearchRequest> =
        (0..queries.len()).map(|_| SearchRequest::knn(10).build()).collect();
    for kind in [IndexKind::Vp, IndexKind::Ball, IndexKind::Cover, IndexKind::MTree] {
        let index = kind.build(store.view(), BoundKind::Mult);
        let mut ctx = QueryContext::new();
        let mut resps = Vec::new();
        index.search_batch_into(&queries, &reqs, &mut ctx, &mut resps);
        let want = sequential(index.as_ref(), &queries, &reqs);
        for (qi, (b, s)) in resps.iter().zip(&want).enumerate() {
            assert_bits_eq(&s.hits, &b.hits, &format!("dup {} q{qi}", kind.name()));
        }
        let batch_nodes: u64 = resps.iter().map(|r| r.stats.nodes_visited).sum();
        let seq_nodes: u64 = want.iter().map(|r| r.stats.nodes_visited).sum();
        assert!(batch_nodes > 0, "{}: batch visited nothing", kind.name());
        assert!(
            batch_nodes < seq_nodes,
            "{}: shared frontier visited {batch_nodes} nodes vs {seq_nodes} sequential",
            kind.name()
        );
    }
}

// --- 5. optioned plans fall back, bitwise ----------------------------------

#[test]
fn optioned_batches_fall_back_and_match_sequential() {
    let store = uniform_sphere_store(600, 10, 21);
    let queries: Vec<DenseVec> = uniform_sphere(6, 10, 22);
    let allow: Vec<u64> = (0..600).step_by(3).collect();
    let reqs: Vec<SearchRequest> = (0..queries.len())
        .map(|i| match i % 4 {
            0 => SearchRequest::knn(5).allow(allow.clone()).build(),
            1 => SearchRequest::range(0.0).deny(vec![1, 2, 3]).build(),
            2 => SearchRequest::knn(4).kernel(KernelKind::Scalar).build(),
            _ => SearchRequest::range(-1.0).budget(500).build(),
        })
        .collect();
    assert!(reqs.iter().any(|r| !r.is_plain()));
    for kind in ALL_KINDS {
        let index = kind.build(store.view(), BoundKind::Mult);
        assert_batch_matches(
            index.as_ref(),
            &queries,
            &reqs,
            &format!("optioned {}", kind.name()),
        );
    }
}

// --- 6. sharded corpora -----------------------------------------------------

#[test]
fn shard_batches_match_per_query_search_ctx() {
    for kernel in ALL_KERNELS {
        let store = uniform_sphere_store(1500, 12, 5).with_kernel(kernel);
        let shards = build_shards(&store, 3, IndexKind::Vp, BoundKind::Mult, 0);
        assert_eq!(shards.len(), 3);
        let queries: Vec<DenseVec> = uniform_sphere(8, 12, 6);
        let plain: Vec<SearchRequest> = (0..queries.len())
            .map(|i| {
                if i % 2 == 0 {
                    SearchRequest::knn(6).build()
                } else {
                    SearchRequest::range(0.2).build()
                }
            })
            .collect();
        // A second round carrying global-id filters exercises the shard's
        // per-request localization (and the per-query fallback under it).
        let filtered: Vec<SearchRequest> = (0..queries.len())
            .map(|i| {
                if i % 2 == 0 {
                    SearchRequest::knn(6).allow((0..1500).step_by(2).collect()).build()
                } else {
                    SearchRequest::range(0.2).build()
                }
            })
            .collect();
        for shard in &shards {
            for reqs in [&plain, &filtered] {
                let mut ctx = QueryContext::new();
                let mut resps = Vec::new();
                shard.search_batch_ctx(&queries, reqs, &mut ctx, &mut resps);
                for (qi, q) in queries.iter().enumerate() {
                    let mut c2 = QueryContext::new();
                    let (hits, _, truncated) = shard.search_ctx(q, &reqs[qi], &mut c2);
                    let what = format!("shard {} / {} q{qi}", shard.base, kernel.name());
                    assert_bits_eq(&hits, &resps[qi].hits, &what);
                    assert_eq!(truncated, resps[qi].truncated, "{what} truncated");
                }
            }
        }
    }
}

// --- 7. mutable (ingest) corpora --------------------------------------------

#[test]
fn ingest_batches_match_per_query_search_ctx() {
    for kernel in ALL_KERNELS {
        // Two sealed generations plus staged memtable rows plus
        // tombstones: the whole batch fans out over one snapshot.
        let cfg = IngestConfig {
            seal_threshold: 500,
            background: false,
            kernel,
            ..IngestConfig::new(12)
        };
        let corpus = IngestCorpus::new(cfg).unwrap();
        for r in &uniform_sphere(1200, 12, 31) {
            corpus.insert(r.as_slice().to_vec()).unwrap();
        }
        for id in (0..1200u64).step_by(97) {
            assert!(corpus.delete(id));
        }
        let st = corpus.stats();
        assert!(st.generations >= 2 && st.memtable_items > 0, "{st:?}");

        let queries: Vec<DenseVec> = uniform_sphere(8, 12, 33);
        let reqs: Vec<SearchRequest> = (0..queries.len())
            .map(|i| {
                if i % 2 == 0 {
                    SearchRequest::knn(9).build()
                } else {
                    SearchRequest::range(0.1).build()
                }
            })
            .collect();
        let mut ctx = QueryContext::new();
        let mut outs: Vec<Vec<(u64, f64)>> = Vec::new();
        let mut metas: Vec<(QueryStats, bool)> = Vec::new();
        corpus.search_batch_ctx(&queries, &reqs, &mut ctx, &mut outs, &mut metas);
        assert_eq!(outs.len(), queries.len());
        for (qi, q) in queries.iter().enumerate() {
            let mut c2 = QueryContext::new();
            let mut out = Vec::new();
            let (_, truncated) = corpus.search_ctx(q, &reqs[qi], &mut c2, &mut out);
            let what = format!("ingest batch / {} q{qi}", kernel.name());
            assert_bits_eq64(&out, &outs[qi], &what);
            assert_eq!(truncated, metas[qi].1, "{what} truncated");
            assert!(metas[qi].0.sim_evals > 0, "{what} evals");
        }
    }
}
