//! Integration: the full serving stack — coordinator modes (index / engine /
//! hybrid), TCP server, and cross-mode agreement on the same corpus.

use simetra::bounds::BoundKind;
use simetra::coordinator::{
    server, BatchConfig, Coordinator, CoordinatorConfig, ExecMode, IndexKind, Request, Response,
};
use simetra::data::{vmf_mixture, VmfSpec};
use simetra::index::{LinearScan, QueryStats, SimilarityIndex};
use simetra::metrics::DenseVec;

fn artifact_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: no artifacts/ (run `make artifacts`)");
        None
    }
}

fn corpus(n: usize, d: usize) -> Vec<DenseVec> {
    vmf_mixture(&VmfSpec { n, dim: d, clusters: 16, kappa: 60.0, seed: 7 }).0
}

fn config(mode: ExecMode, artifacts: Option<std::path::PathBuf>) -> CoordinatorConfig {
    CoordinatorConfig {
        n_shards: 2,
        index: IndexKind::Vp,
        bound: BoundKind::Mult,
        mode,
        batch: BatchConfig {
            max_batch: 8,
            max_wait: std::time::Duration::from_millis(1),
            queue_depth: 256,
        },
        artifact_dir: artifacts,
        hybrid_pivots: 16,
        kernel: None,
    }
}

/// Build a coordinator for an engine-backed mode, skipping (not failing)
/// only when the engine is the default non-`pjrt` build's stub; any other
/// construction error on a real `pjrt` build still fails loudly.
fn engine_coordinator(
    pts: &[DenseVec],
    cfg: CoordinatorConfig,
) -> Option<Coordinator> {
    match Coordinator::new(pts.to_vec(), cfg) {
        Ok(c) => Some(c),
        Err(e) if e.to_string().contains("pjrt") => {
            eprintln!("skipping: {e}");
            None
        }
        Err(e) => panic!("coordinator failed to start engine mode: {e}"),
    }
}

#[test]
fn engine_mode_matches_index_mode() {
    let Some(dir) = artifact_dir() else { return };
    let pts = corpus(3000, 128);
    let index_coord = Coordinator::new(pts.clone(), config(ExecMode::Index, None)).unwrap();
    let Some(engine_coord) = engine_coordinator(&pts, config(ExecMode::Engine, Some(dir)))
    else {
        return;
    };
    for qi in [0usize, 1500, 2999] {
        let v = pts[qi].as_slice().to_vec();
        let (a, _) = index_coord.knn(v.clone(), 5).unwrap();
        let (b, _) = engine_coord.knn(v, 5).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            // f32 artifact vs f64 native: scores agree to 1e-4.
            assert!((x.score - y.score).abs() < 1e-4, "{x:?} vs {y:?}");
        }
        assert_eq!(a[0].id, qi as u64);
        assert_eq!(b[0].id, qi as u64);
    }
}

#[test]
fn hybrid_mode_matches_index_mode() {
    let Some(dir) = artifact_dir() else { return };
    let pts = corpus(2000, 64);
    let index_coord = Coordinator::new(pts.clone(), config(ExecMode::Index, None)).unwrap();
    let Some(hybrid_coord) = engine_coordinator(&pts, config(ExecMode::Hybrid, Some(dir)))
    else {
        return;
    };
    for qi in [0usize, 999, 1999] {
        let v = pts[qi].as_slice().to_vec();
        let (a, _) = index_coord.knn(v.clone(), 7).unwrap();
        let (b, evals) = hybrid_coord.knn(v.clone(), 7).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert!((x.score - y.score).abs() < 1e-6, "{x:?} vs {y:?}");
        }
        // The hybrid path must actually prune (clustered corpus).
        assert!(evals < 2000, "hybrid did not prune: {evals} evals");

        let (ra, _) = index_coord.range(v.clone(), 0.8).unwrap();
        let (rb, _) = hybrid_coord.range(v, 0.8).unwrap();
        assert_eq!(ra.len(), rb.len());
        for (x, y) in ra.iter().zip(&rb) {
            assert_eq!(x.id, y.id);
        }
    }
}

#[test]
fn every_index_kind_serves_correctly() {
    let pts = corpus(600, 32);
    let lin = LinearScan::build(pts.clone());
    for kind in [
        IndexKind::Linear,
        IndexKind::Vp,
        IndexKind::Ball,
        IndexKind::MTree,
        IndexKind::Cover,
        IndexKind::Laesa,
        IndexKind::Gnat,
    ] {
        let mut cfg = config(ExecMode::Index, None);
        cfg.index = kind;
        let coord = Coordinator::new(pts.clone(), cfg).unwrap();
        let (hits, _) = coord.knn(pts[123].as_slice().to_vec(), 5).unwrap();
        let mut st = QueryStats::default();
        let want = lin.knn(&pts[123], 5, &mut st);
        for (h, (_, s)) in hits.iter().zip(&want) {
            assert!((h.score - s).abs() < 1e-9, "{kind:?}");
        }
        assert_eq!(hits[0].id, 123, "{kind:?}");
    }
}

#[test]
fn tcp_server_end_to_end_with_engine() {
    let Some(dir) = artifact_dir() else { return };
    let pts = corpus(1500, 128);
    let Some(coord) = engine_coordinator(&pts, config(ExecMode::Engine, Some(dir))) else {
        return;
    };
    let server_handle = server::serve(coord, "127.0.0.1:0").unwrap();
    let mut client = server::Client::connect(server_handle.addr()).unwrap();
    let hits = client.knn(pts[42].as_slice().to_vec(), 3).unwrap();
    assert_eq!(hits[0].id, 42);
    match client.request(&Request::Stats).unwrap() {
        Response::Stats(s) => {
            assert!(s.engine_calls >= 1, "engine was not used: {s:?}");
            assert_eq!(s.corpus_size, 1500);
        }
        other => panic!("{other:?}"),
    }
}

#[test]
fn batched_load_through_engine_mode() {
    let Some(dir) = artifact_dir() else { return };
    let pts = corpus(2000, 128);
    let Some(coord) = engine_coordinator(&pts, config(ExecMode::Engine, Some(dir))) else {
        return;
    };
    let mut handles = Vec::new();
    for qi in 0..32usize {
        let coord = coord.clone();
        let v = pts[qi * 60].as_slice().to_vec();
        handles.push(std::thread::spawn(move || coord.knn(v, 4).unwrap()));
    }
    for (qi, h) in handles.into_iter().enumerate() {
        let (hits, _) = h.join().unwrap();
        assert_eq!(hits[0].id, (qi * 60) as u64);
    }
    let stats = coord.stats();
    assert_eq!(stats.queries, 32);
    // Batching must have grouped queries: fewer batches than queries.
    assert!(stats.batches < 32, "no batching happened: {}", stats.batches);
}
