//! Property sweep: every index x every bound kind x every workload shape
//! returns EXACTLY the linear scan's results. This is the load-bearing
//! correctness guarantee of the whole system — the triangle inequality may
//! only ever save work, never results.
//!
//! (Hand-rolled property testing: the offline build has no proptest; we
//! sweep a seeded randomized grid instead, which is what proptest would
//! shrink from anyway.)

use simetra::bounds::BoundKind;
use simetra::data::{uniform_sphere, vmf_mixture, zipf_corpus, VmfSpec, ZipfSpec};
use simetra::index::{
    BallTree, CoverTree, Gnat, Laesa, LinearScan, MTree, QueryStats, SimilarityIndex, VpTree,
};
use simetra::metrics::DenseVec;
use simetra::sparse::SparseVec;
use simetra::util::Rng;

fn build_all(
    pts: &[DenseVec],
    bound: BoundKind,
) -> Vec<Box<dyn SimilarityIndex<DenseVec>>> {
    vec![
        Box::new(VpTree::build(pts.to_vec(), bound, 97)),
        Box::new(BallTree::build(pts.to_vec(), bound, 8)),
        Box::new(MTree::build(pts.to_vec(), bound, 8)),
        Box::new(CoverTree::build(pts.to_vec(), bound)),
        Box::new(Laesa::build(pts.to_vec(), bound, 12)),
        Box::new(Gnat::build(pts.to_vec(), bound, 6)),
    ]
}

fn assert_same_range(
    idx: &dyn SimilarityIndex<DenseVec>,
    lin: &LinearScan<Vec<DenseVec>>,
    q: &DenseVec,
    tau: f64,
    ctx: &str,
) {
    let mut s1 = QueryStats::default();
    let mut s2 = QueryStats::default();
    let a = idx.range(q, tau, &mut s1);
    let b = lin.range(q, tau, &mut s2);
    assert_eq!(a, b, "range mismatch: {ctx} tau={tau} index={}", idx.name());
}

fn assert_same_knn(
    idx: &dyn SimilarityIndex<DenseVec>,
    lin: &LinearScan<Vec<DenseVec>>,
    q: &DenseVec,
    k: usize,
    ctx: &str,
) {
    let mut s1 = QueryStats::default();
    let mut s2 = QueryStats::default();
    let a = idx.knn(q, k, &mut s1);
    let b = lin.knn(q, k, &mut s2);
    assert_eq!(a.len(), b.len(), "{ctx} index={}", idx.name());
    for (i, ((_, x), (_, y))) in a.iter().zip(&b).enumerate() {
        assert!(
            (x - y).abs() < 1e-12,
            "knn sim mismatch at rank {i}: {x} vs {y} ({ctx}, index={})",
            idx.name()
        );
    }
}

#[test]
fn exactness_sweep_uniform_sphere() {
    let mut rng = Rng::seed_from_u64(2024);
    for trial in 0..6 {
        let n = 50 + rng.below(400);
        let d = 2 + rng.below(48);
        let pts = uniform_sphere(n, d, 1000 + trial);
        let lin = LinearScan::build(pts.clone());
        let bound = BoundKind::ALL[rng.below(BoundKind::ALL.len())];
        let ctx = format!("uniform trial={trial} n={n} d={d} bound={}", bound.name());
        for idx in build_all(&pts, bound) {
            for _ in 0..3 {
                let q = &pts[rng.below(n)];
                let tau = rng.uniform(-0.5, 0.95);
                assert_same_range(idx.as_ref(), &lin, q, tau, &ctx);
                let k = 1 + rng.below(20);
                assert_same_knn(idx.as_ref(), &lin, q, k, &ctx);
            }
        }
    }
}

#[test]
fn exactness_sweep_clustered() {
    let mut rng = Rng::seed_from_u64(77);
    for trial in 0..4 {
        let (pts, _) = vmf_mixture(&VmfSpec {
            n: 300 + rng.below(300),
            dim: 4 + rng.below(32),
            clusters: 1 + rng.below(12),
            kappa: rng.uniform(0.0, 150.0),
            seed: 2000 + trial,
        });
        let lin = LinearScan::build(pts.clone());
        let bound = BoundKind::ALL[rng.below(BoundKind::ALL.len())];
        let ctx = format!("vmf trial={trial} bound={}", bound.name());
        for idx in build_all(&pts, bound) {
            let q = &pts[rng.below(pts.len())];
            assert_same_range(idx.as_ref(), &lin, q, 0.9, &ctx);
            assert_same_range(idx.as_ref(), &lin, q, 0.2, &ctx);
            assert_same_knn(idx.as_ref(), &lin, q, 10, &ctx);
        }
    }
}

#[test]
fn exactness_with_out_of_corpus_queries() {
    // Queries that are NOT corpus members (the serving case).
    let pts = uniform_sphere(400, 16, 3030);
    let queries = uniform_sphere(10, 16, 3031);
    let lin = LinearScan::build(pts.clone());
    for bound in [BoundKind::Mult, BoundKind::Euclidean, BoundKind::ArccosFast] {
        for idx in build_all(&pts, bound) {
            for q in &queries {
                assert_same_range(idx.as_ref(), &lin, q, 0.5, "out-of-corpus");
                assert_same_knn(idx.as_ref(), &lin, q, 5, "out-of-corpus");
            }
        }
    }
}

#[test]
fn exactness_on_sparse_vectors_via_laesa() {
    // Sparse text-like corpus: generic-over-V indexes must work on SparseVec.
    let docs = zipf_corpus(&ZipfSpec {
        n_docs: 400,
        vocab: 3000,
        doc_len: 50,
        ..Default::default()
    });
    let lin = LinearScan::build(docs.clone());
    let laesa = Laesa::build(docs.clone(), BoundKind::Mult, 16);
    let vp = VpTree::build(docs.clone(), BoundKind::Mult, 5);
    let cover = CoverTree::build(docs.clone(), BoundKind::Mult);
    let mut s1 = QueryStats::default();
    let mut s2 = QueryStats::default();
    for qi in [0usize, 100, 399] {
        let q: &SparseVec = &docs[qi];
        for tau in [0.6, 0.2] {
            let want = lin.range(q, tau, &mut s2);
            assert_eq!(laesa.range(q, tau, &mut s1), want);
            assert_eq!(vp.range(q, tau, &mut s1), want);
            assert_eq!(cover.range(q, tau, &mut s1), want);
        }
        let want = lin.knn(q, 8, &mut s2);
        for idx in [
            &laesa as &dyn SimilarityIndex<SparseVec>,
            &vp,
            &cover,
        ] {
            let got = idx.knn(q, 8, &mut s1);
            for ((_, x), (_, y)) in got.iter().zip(&want) {
                assert!((x - y).abs() < 1e-12);
            }
        }
    }
}

#[test]
fn degenerate_corpora() {
    // All-identical, antipodal pairs, and tiny corpora must not break any
    // index or bound.
    let same = vec![DenseVec::new(vec![1.0, 2.0, 3.0]); 30];
    let mut anti = Vec::new();
    for i in 0..20 {
        let sign = if i % 2 == 0 { 1.0 } else { -1.0 };
        anti.push(DenseVec::new(vec![sign, 0.0, 0.0]));
    }
    for pts in [same, anti] {
        let lin = LinearScan::build(pts.clone());
        for bound in BoundKind::ALL {
            for idx in build_all(&pts, bound) {
                let q = &pts[0];
                assert_same_knn(idx.as_ref(), &lin, q, 5, "degenerate");
                assert_same_range(idx.as_ref(), &lin, q, 0.99, "degenerate");
                assert_same_range(idx.as_ref(), &lin, q, -1.0, "degenerate");
            }
        }
    }
}

#[test]
fn pruning_is_monotone_in_bound_tightness() {
    // Fig. 3's order, observed operationally: a tighter bound never needs
    // more similarity evaluations than a looser one on the same tree shape.
    let (pts, _) =
        vmf_mixture(&VmfSpec { n: 3000, dim: 16, clusters: 24, kappa: 90.0, seed: 5050 });
    let chains = [
        [BoundKind::Mult, BoundKind::MultLb1, BoundKind::MultLb2],
        [BoundKind::Mult, BoundKind::Euclidean, BoundKind::EuclLb],
    ];
    for chain in chains {
        let mut prev_evals = 0u64;
        for (i, bound) in chain.iter().enumerate() {
            let idx = VpTree::build(pts.clone(), *bound, 11); // same seed => same tree
            let mut stats = QueryStats::default();
            for qi in 0..20 {
                idx.range(&pts[qi * 150], 0.85, &mut stats);
            }
            if i > 0 {
                assert!(
                    stats.sim_evals >= prev_evals,
                    "looser bound {} beat tighter one: {} < {prev_evals}",
                    bound.name(),
                    stats.sim_evals
                );
            }
            prev_evals = stats.sim_evals;
        }
    }
}
