//! Generational-ingest guarantees, end to end:
//!
//! 1. **Exactness under churn**: after any interleaving of insert /
//!    delete / flush / compact, `knn` and `range` results are
//!    byte-identical (ids *and* similarities) to a linear scan over the
//!    surviving logical corpus — checked against an independent shadow
//!    copy that normalizes with the same arithmetic, across 3 seeds and
//!    2 index kinds.
//! 2. **Lock-free reads**: queries running concurrently with 100
//!    seal/compact cycles never block, never tear, and always return the
//!    oracle answer (the logical corpus is held constant while physical
//!    layout churns underneath).
//! 3. **Protocol robustness**: the new insert/delete/flush/compact ops
//!    work over TCP, and malformed lines (unknown op, missing field, NaN
//!    component, non-finite values) produce `Response::Error`, never a
//!    dropped connection.
//! 4. **Soak smoke** (`SIMETRA_BENCH_QUICK=1`, i.e. CI): 10k
//!    inserts/deletes interleaved with background-thread queries and
//!    background maintenance — no panics, exact results at quiesce.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use simetra::coordinator::{server, Coordinator, CoordinatorConfig, IndexKind, Response};
use simetra::ingest::{IngestConfig, IngestCorpus};
use simetra::sync::{AtomicBool, Ordering};
use simetra::metrics::DenseVec;
use simetra::storage::{dot_slice, normalize_row};
use simetra::util::Rng;

/// The oracle: a linear scan over the shadow of the surviving logical
/// corpus, sorted under the crate-wide (sim desc, id asc) order. The
/// shadow stores rows normalized with the same `normalize_row` the ingest
/// path uses, so similarities must match bit for bit.
fn shadow_knn(shadow: &BTreeMap<u64, Vec<f32>>, q: &[f32], k: usize) -> Vec<(u64, f64)> {
    let mut hits: Vec<(u64, f64)> =
        shadow.iter().map(|(&id, row)| (id, dot_slice(q, row))).collect();
    hits.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    hits.truncate(k);
    hits
}

fn shadow_range(shadow: &BTreeMap<u64, Vec<f32>>, q: &[f32], tau: f64) -> Vec<(u64, f64)> {
    let mut hits: Vec<(u64, f64)> = shadow
        .iter()
        .map(|(&id, row)| (id, dot_slice(q, row)))
        .filter(|&(_, s)| s >= tau)
        .collect();
    hits.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    hits
}

fn random_raw(rng: &mut Rng, d: usize) -> Vec<f32> {
    (0..d).map(|_| rng.normal() as f32).collect()
}

/// Insert into corpus and shadow with identical normalization.
fn insert_both(
    corpus: &IngestCorpus,
    shadow: &mut BTreeMap<u64, Vec<f32>>,
    live: &mut Vec<u64>,
    raw: Vec<f32>,
) {
    let id = corpus.insert(raw.clone()).unwrap();
    let mut row = raw;
    normalize_row(&mut row);
    shadow.insert(id, row);
    live.push(id);
}

fn sync_cfg(dim: usize, kind: IndexKind) -> IngestConfig {
    IngestConfig {
        index: kind,
        seal_threshold: 48,
        max_generations: 3,
        background: false,
        ..IngestConfig::new(dim)
    }
}

#[test]
fn churn_stays_byte_identical_to_linear_scan() {
    let dim = 12;
    for &kind in &[IndexKind::Vp, IndexKind::Ball] {
        for seed in [11u64, 22, 33] {
            let corpus = IngestCorpus::new(sync_cfg(dim, kind)).unwrap();
            let mut rng = Rng::seed_from_u64(seed);
            let mut shadow: BTreeMap<u64, Vec<f32>> = BTreeMap::new();
            let mut live: Vec<u64> = Vec::new();
            for step in 0..500 {
                let roll = rng.below(100);
                if roll < 55 {
                    insert_both(&corpus, &mut shadow, &mut live, random_raw(&mut rng, dim));
                } else if roll < 70 && !live.is_empty() {
                    let id = live.swap_remove(rng.below(live.len()));
                    assert!(corpus.delete(id), "step {step}: live id {id} not deletable");
                    assert!(!corpus.delete(id), "step {step}: double delete not a no-op");
                    shadow.remove(&id);
                } else if roll < 75 {
                    corpus.flush();
                } else if roll < 80 {
                    corpus.compact();
                } else {
                    let q = DenseVec::new(random_raw(&mut rng, dim));
                    let ctx = format!("kind {kind:?} seed {seed} step {step}");
                    if rng.below(2) == 0 {
                        let k = 1 + rng.below(12);
                        let (got, _) = corpus.knn(&q, k);
                        assert_eq!(got, shadow_knn(&shadow, q.as_slice(), k), "knn {ctx}");
                    } else {
                        let tau = rng.uniform(-0.2, 0.6);
                        let (got, _) = corpus.range(&q, tau);
                        assert_eq!(got, shadow_range(&shadow, q.as_slice(), tau), "range {ctx}");
                    }
                }
            }
            // Quiesce: everything sealed and merged, tombstones resolved —
            // and still byte-identical.
            corpus.flush();
            corpus.compact();
            let st = corpus.stats();
            assert_eq!(st.live, shadow.len() as u64, "kind {kind:?} seed {seed}");
            assert_eq!(st.tombstones, 0);
            assert!(st.generations <= 1);
            assert_eq!(st.memtable_items, 0);
            for _ in 0..5 {
                let q = DenseVec::new(random_raw(&mut rng, dim));
                let (got, _) = corpus.knn(&q, 10);
                assert_eq!(got, shadow_knn(&shadow, q.as_slice(), 10));
                let (got, _) = corpus.range(&q, 0.1);
                assert_eq!(got, shadow_range(&shadow, q.as_slice(), 0.1));
            }
        }
    }
}

#[test]
fn concurrent_queries_stay_exact_during_100_seal_compact_cycles() {
    let dim = 8;
    let corpus = Arc::new(IngestCorpus::new(sync_cfg(dim, IndexKind::Vp)).unwrap());
    let mut rng = Rng::seed_from_u64(77);
    let mut shadow: BTreeMap<u64, Vec<f32>> = BTreeMap::new();
    let mut live: Vec<u64> = Vec::new();
    for _ in 0..200 {
        insert_both(&corpus, &mut shadow, &mut live, random_raw(&mut rng, dim));
    }
    corpus.flush();
    corpus.compact();

    let q = DenseVec::new(random_raw(&mut rng, dim));
    let oracle = shadow_knn(&shadow, q.as_slice(), 10);
    assert_eq!(corpus.knn(&q, 10).0, oracle, "oracle mismatch before churn");

    // Physical churn with a constant logical answer: each cycle inserts a
    // throwaway row at similarity -1 to the query (so it can never enter
    // the top-10 of a 200-row corpus), tombstones it, seals, and fully
    // compacts. Readers must see the oracle answer at every instant.
    let stop = Arc::new(AtomicBool::new(false));
    let mut readers = Vec::new();
    for _ in 0..4 {
        let corpus = corpus.clone();
        let stop = stop.clone();
        let q = q.clone();
        let oracle = oracle.clone();
        readers.push(std::thread::spawn(move || {
            let mut queries = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let (got, _) = corpus.knn(&q, 10);
                assert_eq!(got, oracle, "query diverged during seal/compact churn");
                queries += 1;
            }
            queries
        }));
    }
    let anti_q: Vec<f32> = q.as_slice().iter().map(|&v| -v).collect();
    for _ in 0..100 {
        let id = corpus.insert(anti_q.clone()).unwrap();
        assert!(corpus.delete(id));
        corpus.flush();
        corpus.compact();
    }
    stop.store(true, Ordering::Relaxed);
    for r in readers {
        assert!(r.join().unwrap() > 0, "reader thread made no progress");
    }
    let st = corpus.stats();
    assert!(st.compactions >= 100, "{st:?}");
    assert!(st.seals >= 100, "{st:?}");
    assert_eq!(st.live, 200);
    assert_eq!(corpus.knn(&q, 10).0, oracle);
}

#[test]
fn tcp_ingest_ops_and_protocol_robustness() {
    let dim = 4;
    let coord = Coordinator::new_mutable(
        CoordinatorConfig::default(),
        IngestConfig { seal_threshold: 8, background: false, ..IngestConfig::new(dim) },
    )
    .unwrap();
    let server_handle = server::serve(coord, "127.0.0.1:0").unwrap();
    let mut client = server::Client::connect(server_handle.addr()).unwrap();

    // insert -> query -> delete -> compact -> query, over the wire.
    let mut rng = Rng::seed_from_u64(5);
    let mut ids = Vec::new();
    for _ in 0..20 {
        ids.push(client.insert(random_raw(&mut rng, dim)).unwrap());
    }
    assert_eq!(ids, (0..20u64).collect::<Vec<_>>());
    let probe = random_raw(&mut rng, dim);
    let hits = client.knn(probe.clone(), 5).unwrap();
    assert_eq!(hits.len(), 5);
    let victim = hits[0].id;
    assert!(client.delete(victim).unwrap());
    assert!(!client.delete(victim).unwrap(), "double delete over the wire");
    let hits = client.knn(probe.clone(), 5).unwrap();
    assert!(hits.iter().all(|h| h.id != victim), "tombstoned id served");
    client.flush().unwrap();
    client.compact().unwrap();
    let stats = client.stats().unwrap();
    assert_eq!(stats.corpus_size, 19);
    assert_eq!(stats.generations, 1);
    assert_eq!(stats.tombstones, 0);
    assert_eq!(stats.memtable_items, 0);
    assert_eq!(stats.inserts, 20);
    assert_eq!(stats.deletes, 1);
    assert!(stats.seals >= 1 && stats.compactions >= 1);
    let hits = client.knn(probe, 19).unwrap();
    assert_eq!(hits.len(), 19);

    // Malformed lines all produce Response::Error on a live connection:
    // unknown op, missing fields, a NaN component (not valid JSON), a
    // parseable-but-infinite value, and plain garbage.
    let malformed: [&[u8]; 6] = [
        b"{\"op\":\"explode\"}\n",
        b"{\"op\":\"insert\"}\n",
        b"{\"op\":\"insert\",\"vector\":[NaN]}\n",
        b"{\"op\":\"insert\",\"vector\":[1e999,0,0,0]}\n",
        b"{\"op\":\"delete\"}\n",
        b"{not json}\n",
    ];
    for raw in malformed {
        match client.request_raw(raw).unwrap() {
            Response::Error { .. } => {}
            other => panic!("{:?} for {:?}", other, String::from_utf8_lossy(raw)),
        }
    }
    // Wrong dimension is a clean error even though the protocol line is
    // well-formed.
    assert!(client.insert(vec![1.0; 3]).is_err());
    // The connection survived all of it.
    let hits = client.knn(vec![1.0, 0.0, 0.0, 0.0], 1).unwrap();
    assert_eq!(hits.len(), 1);
}

#[test]
fn ingest_soak_smoke() {
    // Gated: runs under SIMETRA_BENCH_QUICK=1 (set by CI) to keep plain
    // local `cargo test` fast.
    if std::env::var("SIMETRA_BENCH_QUICK").as_deref() != Ok("1") {
        eprintln!("skipping soak (set SIMETRA_BENCH_QUICK=1 to run)");
        return;
    }
    let dim = 16;
    let corpus = Arc::new(
        IngestCorpus::new(IngestConfig {
            seal_threshold: 256,
            max_generations: 4,
            maintenance_interval: Duration::from_micros(500),
            ..IngestConfig::new(dim)
        })
        .unwrap(),
    );
    let stop = Arc::new(AtomicBool::new(false));
    let reader = {
        let corpus = corpus.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut rng = Rng::seed_from_u64(404);
            let mut queries = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let q = DenseVec::new(random_raw(&mut rng, dim));
                let (hits, _) = corpus.knn(&q, 8);
                assert!(hits.len() <= 8);
                assert!(hits.windows(2).all(|w| w[0].1 >= w[1].1), "unsorted under churn");
                queries += 1;
            }
            queries
        })
    };
    let mut rng = Rng::seed_from_u64(808);
    let mut shadow: BTreeMap<u64, Vec<f32>> = BTreeMap::new();
    let mut live: Vec<u64> = Vec::new();
    for _ in 0..10_000 {
        if rng.below(10) == 0 && !live.is_empty() {
            let id = live.swap_remove(rng.below(live.len()));
            assert!(corpus.delete(id));
            shadow.remove(&id);
        } else {
            insert_both(&corpus, &mut shadow, &mut live, random_raw(&mut rng, dim));
        }
    }
    stop.store(true, Ordering::Relaxed);
    assert!(reader.join().unwrap() > 0);

    // With the write hammer gone, the background sealer must catch up on
    // its own (proof it was alive all along).
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    loop {
        let st = corpus.stats();
        if st.seals >= 1 && st.memtable_items < 256 {
            break;
        }
        assert!(std::time::Instant::now() < deadline, "maintenance stalled: {st:?}");
        std::thread::sleep(Duration::from_millis(1));
    }

    // Quiesce and verify exactness survived the soak.
    corpus.flush();
    corpus.compact();
    let st = corpus.stats();
    assert_eq!(st.live, shadow.len() as u64);
    assert_eq!(st.tombstones, 0);
    for _ in 0..10 {
        let q = DenseVec::new(random_raw(&mut rng, dim));
        let (got, _) = corpus.knn(&q, 10);
        assert_eq!(got, shadow_knn(&shadow, q.as_slice(), 10));
    }
}
