//! Kernel-backend contract sweep (ADR-003), hand-rolled property style
//! like `integration_index_exactness.rs` (seeded randomized grid):
//!
//! 1. **Tier 1 — bitwise**: the `Simd` backend produces bit-identical
//!    similarities to `Scalar` on *every* scan entry point (`for_each_sim`,
//!    `dot_batch`, `scan_topk`, `scan_range`, `scan_ids_topk`,
//!    `scan_ids_range`), over contiguous, sliced, and id-list views, with
//!    sizes straddling all block/lane boundaries.
//! 2. **Tier 2 — exact-after-re-rank**: the `QuantizedI8` backend returns
//!    *byte-identical* final kNN/range results (3 seeds x 2 index kinds)
//!    while spending fewer exact evaluations, because its i8 pre-filter
//!    only skips rows certified to miss the result set.

use simetra::bounds::BoundKind;
use simetra::coordinator::{Coordinator, CoordinatorConfig, IndexKind};
use simetra::data::{uniform_sphere, uniform_sphere_store};
use simetra::index::{KnnHeap, QueryStats, SimilarityIndex};
use simetra::storage::{CorpusStore, CorpusView, KernelKind};

#[test]
fn simd_acceleration_is_active_when_required() {
    // CI's simd matrix leg sets SIMETRA_EXPECT_AVX=1 so the
    // backend-equivalence coverage cannot silently degrade to
    // scalar-vs-scalar on a runner without AVX.
    if std::env::var("SIMETRA_EXPECT_AVX").as_deref() != Ok("1") {
        return;
    }
    let kernel = simetra::storage::SimdKernel::new();
    assert!(kernel.accelerated(), "SIMETRA_EXPECT_AVX=1 but no AVX path is active");
}

/// Assert two result lists are byte-identical: same ids, same f64 bits.
fn assert_bits_eq(a: &[(u32, f64)], b: &[(u32, f64)], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: lengths differ");
    for (i, ((ia, sa), (ib, sb))) in a.iter().zip(b).enumerate() {
        assert_eq!(ia, ib, "{ctx}: id at {i}");
        assert_eq!(sa.to_bits(), sb.to_bits(), "{ctx}: sim bits at {i}");
    }
}

/// Views of the same rows under two backends: (contiguous full, interior
/// slice, id-list selection).
fn view_pairs(a: &CorpusStore, b: &CorpusStore) -> Vec<(String, CorpusView, CorpusView)> {
    let n = a.len();
    let mut ids: Vec<u32> = (0..n as u32).step_by(3).collect();
    ids.reverse(); // non-monotone id list
    vec![
        ("full".into(), a.view(), b.view()),
        ("slice".into(), a.slice(n / 5..n - n / 7), b.slice(n / 5..n - n / 7)),
        ("ids".into(), a.select(ids.clone()), b.select(ids)),
    ]
}

#[test]
fn simd_is_bitwise_identical_to_scalar_on_every_entry_point() {
    for &(n, d) in &[(23usize, 5usize), (64, 8), (100, 17), (257, 96), (400, 64)] {
        let store = uniform_sphere_store(n, d, 1_000 + n as u64);
        let scalar = store.clone().with_kernel(KernelKind::Scalar);
        let simd = store.clone().with_kernel(KernelKind::Simd);
        let q = uniform_sphere(1, d, 9_000 + d as u64).pop().unwrap();
        for (name, va, vb) in view_pairs(&scalar, &simd) {
            let ctx = format!("{name} n={n} d={d}");
            let m = va.len();

            // for_each_sim
            let mut sa = Vec::new();
            let mut sb = Vec::new();
            va.for_each_sim(q.as_slice(), |id, s| sa.push((id, s)));
            vb.for_each_sim(q.as_slice(), |id, s| sb.push((id, s)));
            assert_bits_eq(&sa, &sb, &format!("{ctx} for_each_sim"));

            // dot_batch over a duplicated, shuffled local id list.
            let locals: Vec<u32> = (0..m as u32).rev().chain([0, m as u32 / 2, 0]).collect();
            let mut da = Vec::new();
            let mut db = Vec::new();
            va.dot_batch(q.as_slice(), &locals, &mut da);
            vb.dot_batch(q.as_slice(), &locals, &mut db);
            assert_eq!(da.len(), db.len());
            for (x, y) in da.iter().zip(&db) {
                assert_eq!(x.to_bits(), y.to_bits(), "{ctx} dot_batch");
            }

            // scan_topk
            let mut ha = KnnHeap::new(7);
            let mut hb = KnnHeap::new(7);
            assert_eq!(va.scan_topk(q.as_slice(), &mut ha), vb.scan_topk(q.as_slice(), &mut hb));
            assert_bits_eq(&ha.into_sorted(), &hb.into_sorted(), &format!("{ctx} topk"));

            // scan_range
            let mut ra = Vec::new();
            let mut rb = Vec::new();
            va.scan_range(q.as_slice(), 0.05, &mut ra);
            vb.scan_range(q.as_slice(), 0.05, &mut rb);
            assert_bits_eq(&ra, &rb, &format!("{ctx} range"));

            // scan_ids_topk / scan_ids_range over a bucket-like id list.
            let bucket: Vec<u32> = (0..m as u32).filter(|i| i % 2 == 0).collect();
            let mut ba = KnnHeap::new(4);
            let mut bb = KnnHeap::new(4);
            va.scan_ids_topk(q.as_slice(), &bucket, &mut ba);
            vb.scan_ids_topk(q.as_slice(), &bucket, &mut bb);
            assert_bits_eq(&ba.into_sorted(), &bb.into_sorted(), &format!("{ctx} ids_topk"));
            let mut ga = Vec::new();
            let mut gb = Vec::new();
            va.scan_ids_range(q.as_slice(), &bucket, -0.2, &mut ga);
            vb.scan_ids_range(q.as_slice(), &bucket, -0.2, &mut gb);
            assert_bits_eq(&ga, &gb, &format!("{ctx} ids_range"));
        }
    }
}

#[test]
fn quantized_scans_are_byte_identical_after_rerank() {
    for seed in [1u64, 2, 3] {
        // Above QUANT_MIN_ROWS so the i8 pre-filter actually engages.
        let n = 1200;
        let d = 32;
        let store = uniform_sphere_store(n, d, 40 + seed);
        let exact = store.clone().with_kernel(KernelKind::Scalar);
        let quant = store.clone().with_kernel(KernelKind::QuantizedI8);
        for qseed in [7u64, 8] {
            let q = uniform_sphere(1, d, 900 * seed + qseed).pop().unwrap();
            let mut he = KnnHeap::new(9);
            let mut hq = KnnHeap::new(9);
            let evals_exact = exact.view().scan_topk(q.as_slice(), &mut he);
            let evals_quant = quant.view().scan_topk(q.as_slice(), &mut hq);
            assert_bits_eq(
                &he.into_sorted(),
                &hq.into_sorted(),
                &format!("seed={seed} qseed={qseed} topk"),
            );
            assert!(evals_quant <= evals_exact, "{evals_quant} > {evals_exact}");

            let mut re = Vec::new();
            let mut rq = Vec::new();
            exact.view().scan_range(q.as_slice(), 0.25, &mut re);
            quant.view().scan_range(q.as_slice(), 0.25, &mut rq);
            assert_bits_eq(&re, &rq, &format!("seed={seed} qseed={qseed} range"));
        }
        // The pre-filter actually ran, and re-ranks never exceed it.
        let kc = quant.kernel().counters();
        assert!(kc.quant_prefilter_rows() > 0);
        assert!(kc.quant_rerank_rows() <= kc.quant_prefilter_rows());
    }
}

#[test]
fn quantized_knn_through_indexes_matches_exact_across_seeds_and_kinds() {
    for seed in [11u64, 12, 13] {
        for kind in [IndexKind::Vp, IndexKind::Gnat] {
            let n = 1100;
            let d = 24;
            let store = uniform_sphere_store(n, d, seed * 31);
            let idx_exact =
                kind.build(store.clone().with_kernel(KernelKind::Scalar).view(), BoundKind::Mult);
            let idx_quant = kind.build(
                store.clone().with_kernel(KernelKind::QuantizedI8).view(),
                BoundKind::Mult,
            );
            for qi in [0usize, 399, 811, 1099] {
                let q = store.vec(qi);
                let mut s1 = QueryStats::default();
                let mut s2 = QueryStats::default();
                let a = idx_exact.knn(&q, 6, &mut s1);
                let b = idx_quant.knn(&q, 6, &mut s2);
                assert_bits_eq(&a, &b, &format!("seed={seed} kind={kind:?} knn qi={qi}"));
                let a = idx_exact.range(&q, 0.3, &mut s1);
                let b = idx_quant.range(&q, 0.3, &mut s2);
                assert_bits_eq(&a, &b, &format!("seed={seed} kind={kind:?} range qi={qi}"));
            }
        }
    }
}

#[test]
fn quantized_backend_is_exact_through_a_sharded_coordinator() {
    // Shards give the backend Rows/Gather selections with base > 0 — the
    // only path where the sidecar's absolute-row indexing meets non-zero
    // offsets. Results must still be byte-identical to the exact backend.
    fn cfg(kind: KernelKind) -> CoordinatorConfig {
        CoordinatorConfig { n_shards: 3, kernel: Some(kind), ..Default::default() }
    }
    let store = uniform_sphere_store(1500, 16, 1234);
    let exact = Coordinator::new(store.clone(), cfg(KernelKind::Scalar)).unwrap();
    let quant = Coordinator::new(store.clone(), cfg(KernelKind::QuantizedI8)).unwrap();
    for qi in [0usize, 423, 999, 1499] {
        let q = store.vec(qi).as_slice().to_vec();
        let (a, _) = exact.knn(q.clone(), 8).unwrap();
        let (b, _) = quant.knn(q.clone(), 8).unwrap();
        assert_eq!(a, b, "knn qi={qi}");
        let (a, _) = exact.range(q.clone(), 0.4).unwrap();
        let (b, _) = quant.range(q, 0.4).unwrap();
        assert_eq!(a, b, "range qi={qi}");
    }
    let stats = quant.stats();
    assert_eq!(stats.kernel, "i8");
    assert!(stats.quant_prefilter_rows > 0, "{stats:?}");
    assert!(stats.quant_rerank_rows <= stats.quant_prefilter_rows, "{stats:?}");
}

#[test]
fn quantization_roundtrip_error_is_within_one_127th_per_component() {
    let d = 96;
    let store = uniform_sphere_store(1100, d, 77).with_kernel(KernelKind::QuantizedI8);
    let side = store.quant_sidecar().expect("i8 backend builds a sidecar");
    for row in 0..store.len() {
        let scale = side.scale(row);
        let codes = side.codes(row);
        for (j, &v) in store.row(row).iter().enumerate() {
            let err = (v as f64 - scale * codes[j] as f64).abs();
            // Unit-norm rows: max |component| <= 1, so the rounding error
            // is <= scale/2 <= 1/254 < 1/127.
            assert!(err <= 1.0 / 127.0, "row {row} comp {j}: err {err}");
            assert!(err <= scale * 0.5 + 1e-12, "row {row} comp {j}: err {err}");
        }
    }
}
