//! The observability layer's contracts (ADR-007):
//!
//!  1. Traced (EXPLAIN) searches are **byte-identical** to untraced ones —
//!     including against the shared-frontier batch path the untraced plain
//!     plans ride — across all 7 indexes × {scalar, simd, i8} kernels ×
//!     static, sharded, and mutable (ingest) corpora; and a traced search
//!     really records a non-empty event log.
//!  2. The wire surface: the `explain` op returns the same hits as
//!     `search` plus the trace; the `metrics` op serves well-formed
//!     Prometheus text containing the bound-slack histograms keyed by
//!     index and bound, the per-stage span histograms, per-shard work
//!     counters, and the slow-query ring.

use simetra::bounds::BoundKind;
use simetra::coordinator::router::build_shards;
use simetra::coordinator::{server, Coordinator, CoordinatorConfig, IndexKind};
use simetra::data::{uniform_sphere, uniform_sphere_store};
use simetra::index::SimilarityIndex;
use simetra::ingest::{IngestConfig, IngestCorpus};
use simetra::metrics::DenseVec;
use simetra::query::{QueryContext, SearchRequest, SearchResponse};
use simetra::storage::KernelKind;

const ALL_KINDS: [IndexKind; 7] = [
    IndexKind::Linear,
    IndexKind::Vp,
    IndexKind::Ball,
    IndexKind::MTree,
    IndexKind::Cover,
    IndexKind::Laesa,
    IndexKind::Gnat,
];

const ALL_KERNELS: [KernelKind; 3] =
    [KernelKind::Scalar, KernelKind::Simd, KernelKind::QuantizedI8];

/// Bitwise equality of two result lists: same ids, same f64 bit patterns.
fn assert_bits_eq(a: &[(u32, f64)], b: &[(u32, f64)], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: lengths differ");
    for (pos, ((ia, sa), (ib, sb))) in a.iter().zip(b).enumerate() {
        assert_eq!(ia, ib, "{what}: id at {pos}");
        assert_eq!(sa.to_bits(), sb.to_bits(), "{what}: sim bits at {pos}");
    }
}

fn assert_bits_eq64(a: &[(u64, f64)], b: &[(u64, f64)], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: lengths differ");
    for (pos, ((ia, sa), (ib, sb))) in a.iter().zip(b).enumerate() {
        assert_eq!(ia, ib, "{what}: id at {pos}");
        assert_eq!(sa.to_bits(), sb.to_bits(), "{what}: sim bits at {pos}");
    }
}

/// Alternating kNN / range plans, traced or not.
fn mixed_reqs(n: usize, traced: bool) -> Vec<SearchRequest> {
    (0..n)
        .map(|i| {
            let b = if i % 2 == 0 {
                SearchRequest::knn(8)
            } else {
                SearchRequest::range(0.15)
            };
            if traced {
                b.trace().build()
            } else {
                b.build()
            }
        })
        .collect()
}

// --- 1. traced == untraced, static indexes ---------------------------------

#[test]
fn traced_matches_untraced_static_indexes() {
    let queries: Vec<DenseVec> = uniform_sphere(6, 16, 77);
    let plain = mixed_reqs(queries.len(), false);
    let traced = mixed_reqs(queries.len(), true);
    for kernel in ALL_KERNELS {
        let store = uniform_sphere_store(1200, 16, 76).with_kernel(kernel);
        for kind in ALL_KINDS {
            let index = kind.build(store.view(), BoundKind::Mult);
            let what = format!("{} / {}", kind.name(), kernel.name());
            let mut ctx = QueryContext::new();
            let mut pr: Vec<SearchResponse> = Vec::new();
            let mut tr: Vec<SearchResponse> = Vec::new();
            // The plain batch rides the shared-frontier traversal; the
            // traced batch is non-plain and falls back per query — the
            // strongest form of the byte-identity contract.
            index.search_batch_into(&queries, &plain, &mut ctx, &mut pr);
            index.search_batch_into(&queries, &traced, &mut ctx, &mut tr);
            for (qi, (p, t)) in pr.iter().zip(&tr).enumerate() {
                assert_bits_eq(&p.hits, &t.hits, &format!("{what} q{qi}"));
                assert!(p.trace.is_empty(), "{what} q{qi}: untraced request grew a trace");
                assert!(!t.trace.is_empty(), "{what} q{qi}: traced request has no events");
            }
        }
    }
}

// --- sharded corpora -------------------------------------------------------

#[test]
fn traced_matches_untraced_sharded() {
    for kernel in ALL_KERNELS {
        let store = uniform_sphere_store(1500, 12, 5).with_kernel(kernel);
        for kind in ALL_KINDS {
            let shards = build_shards(&store, 3, kind, BoundKind::Mult, 0);
            let queries: Vec<DenseVec> = uniform_sphere(4, 12, 8);
            let what = format!("{} / {}", kind.name(), kernel.name());
            for shard in &shards {
                let mut ctx = QueryContext::new();
                for (qi, q) in queries.iter().enumerate() {
                    let plain = SearchRequest::knn(6).build();
                    let traced = SearchRequest::knn(6).trace().build();
                    let (ph, ps, _, pt) = shard.search_ctx(q, &plain, &mut ctx);
                    let (th, ts, _, tt) = shard.search_ctx(q, &traced, &mut ctx);
                    assert_bits_eq(&ph, &th, &format!("{what} shard {} q{qi}", shard.base));
                    assert_eq!(ps.sim_evals, ts.sim_evals, "{what} q{qi}: evals differ");
                    assert!(pt.is_empty(), "{what} q{qi}: untraced request grew a trace");
                    assert!(!tt.is_empty(), "{what} q{qi}: traced request has no events");
                }
            }
        }
    }
}

// --- mutable (ingest) corpora ----------------------------------------------

#[test]
fn traced_matches_untraced_mutable_corpus() {
    for kernel in ALL_KERNELS {
        // One sealed generation plus staged memtable rows plus tombstones:
        // the traced fan-out crosses every source kind.
        let cfg = IngestConfig {
            seal_threshold: 600,
            background: false,
            kernel,
            ..IngestConfig::new(12)
        };
        let corpus = IngestCorpus::new(cfg).unwrap();
        for r in &uniform_sphere(700, 12, 31) {
            corpus.insert(r.as_slice().to_vec()).unwrap();
        }
        for id in (0..700u64).step_by(101) {
            assert!(corpus.delete(id));
        }
        let queries: Vec<DenseVec> = uniform_sphere(6, 12, 32);
        let plain = mixed_reqs(queries.len(), false);
        let traced = mixed_reqs(queries.len(), true);
        let mut ctx = QueryContext::new();
        let (mut outs_p, mut metas_p) = (Vec::new(), Vec::new());
        let (mut outs_t, mut metas_t) = (Vec::new(), Vec::new());
        corpus.search_batch_ctx(&queries, &plain, &mut ctx, &mut outs_p, &mut metas_p);
        corpus.search_batch_ctx(&queries, &traced, &mut ctx, &mut outs_t, &mut metas_t);
        for qi in 0..queries.len() {
            let what = format!("ingest / {} q{qi}", kernel.name());
            assert_bits_eq64(&outs_p[qi], &outs_t[qi], &what);
            assert_eq!(metas_p[qi].0.sim_evals, metas_t[qi].0.sim_evals, "{what}: evals");
            assert!(metas_p[qi].2.is_empty(), "{what}: untraced request grew a trace");
            assert!(!metas_t[qi].2.is_empty(), "{what}: traced request has no events");
        }
    }
}

// --- 2. wire surface: explain + metrics ------------------------------------

/// Every non-comment line of a Prometheus text page is `name value` or
/// `name{labels} value` with a numeric value and balanced label braces.
fn assert_prometheus_well_formed(text: &str) {
    for line in text.lines() {
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (_, value) = line.rsplit_once(' ').unwrap_or_else(|| panic!("bad line: {line}"));
        assert!(value.parse::<f64>().is_ok(), "non-numeric value in line: {line}");
        assert_eq!(line.matches('{').count(), line.matches('}').count(), "{line}");
    }
}

#[test]
fn wire_explain_and_metrics_surface() {
    let pts = uniform_sphere(600, 8, 91);
    let coord = Coordinator::new(pts.clone(), CoordinatorConfig::default()).unwrap();
    let server = server::serve(coord, "127.0.0.1:0").unwrap();
    let mut client = server::Client::connect(server.addr()).unwrap();

    // Populate the registries: plain searches feed the stage histograms,
    // shard work cells, latency histogram, and slow-query ring. (Bound
    // slack is recorded on the per-query path only, so the `explain`
    // call below is what guarantees slack samples exist.)
    for i in 0..12usize {
        let req = SearchRequest::knn(5).build();
        let result = client.search(pts[i].as_slice().to_vec(), req).unwrap();
        assert_eq!(result.hits[0].id, i as u64);
    }

    // Explain == search, bit for bit, plus a non-empty trace.
    let req = SearchRequest::knn(5).build();
    let plain = client.search(pts[3].as_slice().to_vec(), req.clone()).unwrap();
    let traced = client.explain(pts[3].as_slice().to_vec(), req).unwrap();
    assert_eq!(plain.hits.len(), traced.hits.len());
    for (a, b) in plain.hits.iter().zip(traced.hits.iter()) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.score.to_bits(), b.score.to_bits());
    }
    assert!(plain.trace.is_empty(), "search replies never carry a trace");
    assert!(!traced.trace.is_empty(), "explain reply carries the event log");

    // The metrics op: one well-formed Prometheus page with the ADR-007
    // families (the default config serves a vp index).
    let text = client.metrics().unwrap();
    assert_prometheus_well_formed(&text);
    assert!(text.contains("# TYPE simetra_queries_total counter"), "{text}");
    assert!(text.contains("# TYPE simetra_request_latency_us histogram"), "{text}");
    assert!(text.contains("# TYPE simetra_bound_slack histogram"), "{text}");
    assert!(text.contains("simetra_bound_slack_count{index=\"vp\",bound=\""), "{text}");
    assert!(text.contains("# TYPE simetra_stage_duration_ns histogram"), "{text}");
    assert!(text.contains("stage=\"traversal\""), "{text}");
    assert!(text.contains("stage=\"parse\""), "{text}");
    assert!(text.contains("simetra_shard_work{shard=\"0\",counter=\"queries\"}"), "{text}");
    assert!(text.contains("simetra_slow_query_latency_us{rank=\"0\",mode=\"knn\""), "{text}");
}
