//! The unified query-execution layer's exactness and allocation contracts
//! (ADR-004):
//!
//!  1. `knn_batch` / `range_batch` through one shared `QueryContext` are
//!     byte-identical to one-at-a-time `knn` / `range` calls, across all
//!     7 indexes × {scalar, simd, i8} kernels × static, sharded, and
//!     mutable (ingest) corpora.
//!  2. One context survives 100 mixed queries across *different* index
//!     types with results unchanged (the frontier type-erasure contract).
//!  3. The steady-state query path performs **zero heap allocations** per
//!     query (counting global allocator, thread-local so parallel tests
//!     don't interfere) — and the ADR-006 batched traversal holds the
//!     same bar: a whole `search_batch_into` batch through a warmed
//!     `BatchContext` arena allocates nothing. Enabling aggregate
//!     observability (ADR-007 bound-slack windows + span timings, all
//!     fixed-capacity) does not move the bar.
//!  4. A quantized traversal builds its `QuantQuery` once per query, no
//!     matter how many leaf buckets it scans (the ROADMAP follow-on).

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use simetra::bounds::BoundKind;
use simetra::coordinator::router::build_shards;
use simetra::coordinator::IndexKind;
use simetra::data::{uniform_sphere, uniform_sphere_store};
use simetra::index::{QueryStats, SimilarityIndex};
use simetra::ingest::{IngestConfig, IngestCorpus};
use simetra::metrics::DenseVec;
use simetra::query::QueryContext;
use simetra::storage::{CorpusStore, KernelKind};

// --- counting allocator ----------------------------------------------------

thread_local! {
    static COUNTING: Cell<bool> = const { Cell::new(false) };
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

/// System allocator that counts allocations made by the *current thread*
/// while that thread has counting enabled — the zero-allocation assertion
/// stays exact even with other tests running in parallel threads.
struct CountingAlloc;

impl CountingAlloc {
    fn note(&self) {
        // try_with: allocation during TLS teardown must not panic.
        let _ = COUNTING.try_with(|c| {
            if c.get() {
                let _ = ALLOCS.try_with(|a| a.set(a.get() + 1));
            }
        });
    }
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        self.note();
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        self.note();
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        self.note();
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn count_allocs(f: impl FnOnce()) -> u64 {
    COUNTING.with(|c| c.set(true));
    ALLOCS.with(|a| a.set(0));
    f();
    COUNTING.with(|c| c.set(false));
    ALLOCS.with(|a| a.get())
}

// --- helpers ---------------------------------------------------------------

const ALL_KINDS: [IndexKind; 7] = [
    IndexKind::Linear,
    IndexKind::Vp,
    IndexKind::Ball,
    IndexKind::MTree,
    IndexKind::Cover,
    IndexKind::Laesa,
    IndexKind::Gnat,
];

const ALL_KERNELS: [KernelKind; 3] =
    [KernelKind::Scalar, KernelKind::Simd, KernelKind::QuantizedI8];

/// Bitwise equality of two result lists: same ids, same f64 bit patterns.
fn assert_bits_eq(a: &[(u32, f64)], b: &[(u32, f64)], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: lengths differ");
    for (pos, ((ia, sa), (ib, sb))) in a.iter().zip(b).enumerate() {
        assert_eq!(ia, ib, "{what}: id at {pos}");
        assert_eq!(sa.to_bits(), sb.to_bits(), "{what}: sim bits at {pos}");
    }
}

fn assert_bits_eq64(a: &[(u64, f64)], b: &[(u64, f64)], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: lengths differ");
    for (pos, ((ia, sa), (ib, sb))) in a.iter().zip(b).enumerate() {
        assert_eq!(ia, ib, "{what}: id at {pos}");
        assert_eq!(sa.to_bits(), sb.to_bits(), "{what}: sim bits at {pos}");
    }
}

// --- 1. batch == sequential, all indexes × kernels -------------------------

#[test]
fn batch_matches_sequential_across_indexes_and_kernels() {
    // Hand-rolled proptest sweep (the repo has no proptest dep): multiple
    // data/query seeds per index × kernel cell. Corpus size stays
    // >= QUANT_MIN_ROWS so the i8 leg really builds a sidecar and takes
    // the pre-filter + re-rank path, not the exact fallback.
    for seed in [99u64, 1234] {
        let rows = uniform_sphere(1200, 16, seed);
        let queries: Vec<DenseVec> = uniform_sphere(10, 16, seed.wrapping_add(7));
        for kernel in ALL_KERNELS {
            let store = CorpusStore::from_rows(rows.clone()).with_kernel(kernel);
            for kind in ALL_KINDS {
                let index = kind.build(store.view(), BoundKind::Mult);
                let what = format!("{} / {} / seed {seed}", kind.name(), kernel.name());
                let mut ctx = QueryContext::new();
                let knn_b = index.knn_batch(&queries, 8, &mut ctx);
                let rng_b = index.range_batch(&queries, 0.15, &mut ctx);
                for (qi, q) in queries.iter().enumerate() {
                    let mut st = QueryStats::default();
                    let a = index.knn(q, 8, &mut st);
                    assert_bits_eq(&a, &knn_b[qi].0, &format!("{what} knn q{qi}"));
                    assert_eq!(st.sim_evals, knn_b[qi].1.sim_evals, "{what} knn evals q{qi}");
                    let r = index.range(q, 0.15, &mut st);
                    assert_bits_eq(&r, &rng_b[qi].0, &format!("{what} range q{qi}"));
                }
            }
        }
    }
}

// --- sharded corpora -------------------------------------------------------

#[test]
fn sharded_batches_match_per_query_results() {
    for kernel in ALL_KERNELS {
        let store = uniform_sphere_store(1500, 12, 5).with_kernel(kernel);
        let shards = build_shards(&store, 3, IndexKind::Vp, BoundKind::Mult, 0);
        assert_eq!(shards.len(), 3);
        let queries: Vec<DenseVec> = uniform_sphere(6, 12, 8);
        for shard in &shards {
            let mut ctx = QueryContext::new();
            let kb = shard.knn_batch(&queries, 5, &mut ctx);
            let rb = shard.range_batch(&queries, 0.2, &mut ctx);
            for (qi, q) in queries.iter().enumerate() {
                let (hits, _) = shard.knn_index(q, 5);
                assert_bits_eq(&hits, &kb[qi].0, &format!("shard {} knn", shard.base));
                let (hits, _) = shard.range_index(q, 0.2);
                assert_bits_eq(&hits, &rb[qi].0, &format!("shard {} range", shard.base));
            }
        }
    }
}

// --- mutable (ingest) corpora ----------------------------------------------

#[test]
fn ingest_context_queries_match_fresh_context_queries() {
    for kernel in ALL_KERNELS {
        // One sealed generation above QUANT_MIN_ROWS (so i8 builds its
        // sidecar on the sealer path) plus staged memtable rows plus
        // tombstones: the whole fan-out runs through one context.
        let cfg = IngestConfig {
            seal_threshold: 1150,
            background: false,
            kernel,
            ..IngestConfig::new(12)
        };
        let corpus = IngestCorpus::new(cfg).unwrap();
        let rows = uniform_sphere(1200, 12, 31);
        for r in &rows {
            corpus.insert(r.as_slice().to_vec()).unwrap();
        }
        for id in (0..1200u64).step_by(97) {
            assert!(corpus.delete(id));
        }
        let st = corpus.stats();
        assert!(st.generations >= 1 && st.memtable_items > 0, "{st:?}");

        let queries: Vec<DenseVec> = uniform_sphere(8, 12, 32);
        let mut ctx = QueryContext::new();
        let mut out = Vec::new();
        for q in &queries {
            let (a, evals_a) = corpus.knn(q, 9);
            let evals_b = corpus.knn_ctx(q, 9, &mut ctx, &mut out);
            assert_bits_eq64(&a, &out, &format!("ingest knn / {}", kernel.name()));
            assert_eq!(evals_a, evals_b, "ingest knn evals / {}", kernel.name());

            let (a, evals_a) = corpus.range(q, 0.1);
            let evals_b = corpus.range_ctx(q, 0.1, &mut ctx, &mut out);
            assert_bits_eq64(&a, &out, &format!("ingest range / {}", kernel.name()));
            assert_eq!(evals_a, evals_b, "ingest range evals / {}", kernel.name());
        }
        assert_eq!(ctx.queries(), 16);
    }
}

// --- 2. one context, 100 mixed queries, mixed index types ------------------

#[test]
fn one_context_survives_100_mixed_queries_across_index_types() {
    let store = uniform_sphere_store(800, 10, 3);
    let indexes: Vec<_> =
        ALL_KINDS.iter().map(|k| k.build(store.view(), BoundKind::Mult)).collect();
    let queries: Vec<DenseVec> = uniform_sphere(100, 10, 4);
    let mut ctx = QueryContext::new();
    let mut out = Vec::new();
    for (qi, q) in queries.iter().enumerate() {
        let index = &indexes[qi % indexes.len()];
        let mut st = QueryStats::default();
        ctx.begin_query();
        if qi % 2 == 0 {
            index.knn_into(q, 7, &mut ctx, &mut out);
            let want = index.knn(q, 7, &mut st);
            assert_bits_eq(&want, &out, &format!("mixed knn q{qi} ({})", index.name()));
        } else {
            let tau = if qi % 3 == 0 { -0.2 } else { 0.25 };
            index.range_into(q, tau, &mut ctx, &mut out);
            let want = index.range(q, tau, &mut st);
            assert_bits_eq(&want, &out, &format!("mixed range q{qi} ({})", index.name()));
        }
    }
    assert_eq!(ctx.queries(), 100);
    let totals = ctx.totals();
    assert!(totals.sim_evals > 0 && totals.nodes_visited >= 100);
}

// --- 3. zero allocations in the steady state -------------------------------

#[test]
fn steady_state_queries_allocate_nothing() {
    for kernel in ALL_KERNELS {
        let store = uniform_sphere_store(2048, 32, 17).with_kernel(kernel);
        if kernel == KernelKind::QuantizedI8 {
            assert!(store.quant_sidecar().is_some(), "sidecar must be live for this leg");
        }
        let queries: Vec<DenseVec> = (0..6usize).map(|i| store.vec(i * 311)).collect();
        for kind in ALL_KINDS {
            let index = kind.build(store.view(), BoundKind::Mult);
            let mut ctx = QueryContext::new();
            let mut out = Vec::new();
            let mut run = |ctx: &mut QueryContext, out: &mut Vec<(u32, f64)>| {
                for q in &queries {
                    ctx.begin_query();
                    index.knn_into(q, 10, ctx, out);
                    ctx.begin_query();
                    index.range_into(q, 0.2, ctx, out);
                }
            };
            // Warm every pooled buffer to its steady-state capacity (two
            // rounds: the second round's lease order is the one the
            // measured round repeats exactly).
            run(&mut ctx, &mut out);
            run(&mut ctx, &mut out);
            let allocs = count_allocs(|| run(&mut ctx, &mut out));
            assert_eq!(
                allocs,
                0,
                "steady-state {} / {} allocated {} times per 12 queries",
                kind.name(),
                kernel.name(),
                allocs
            );
        }
    }
}

#[test]
fn steady_state_batches_allocate_nothing() {
    use simetra::query::{SearchRequest, SearchResponse};
    for kernel in ALL_KERNELS {
        let store = uniform_sphere_store(2048, 32, 17).with_kernel(kernel);
        let queries: Vec<DenseVec> = (0..8usize).map(|i| store.vec(i * 211)).collect();
        // A mixed-mode batch arms every slot shape the arena has.
        let reqs: Vec<SearchRequest> = (0..queries.len())
            .map(|i| {
                if i % 2 == 0 {
                    SearchRequest::knn(10).build()
                } else {
                    SearchRequest::range(0.2).build()
                }
            })
            .collect();
        // A uniform Ptolemaic override stays on the shared batched path and
        // pins the pivot-pair refinement math as allocation-free too.
        let ptol_reqs: Vec<SearchRequest> = (0..queries.len())
            .map(|_| SearchRequest::knn(10).bound(BoundKind::Ptolemaic).build())
            .collect();
        for kind in ALL_KINDS {
            let index = kind.build(store.view(), BoundKind::Mult);
            let mut ctx = QueryContext::new();
            let mut resps: Vec<SearchResponse> = Vec::new();
            let mut run = |ctx: &mut QueryContext, resps: &mut Vec<SearchResponse>| {
                index.search_batch_into(&queries, &reqs, ctx, resps);
                index.search_batch_into(&queries, &ptol_reqs, ctx, resps);
            };
            // Two warm rounds: the BatchContext arena, per-slot heaps and
            // scratches, response buffers, and lease pools all reach their
            // steady-state capacity before the counting round.
            run(&mut ctx, &mut resps);
            run(&mut ctx, &mut resps);
            let allocs = count_allocs(|| run(&mut ctx, &mut resps));
            assert_eq!(
                allocs,
                0,
                "steady-state batch {} / {} allocated {} times",
                kind.name(),
                kernel.name(),
                allocs
            );
        }
    }
}

#[test]
fn bound_parsing_allocates_nothing() {
    // The wire/CLI hot path parses a bound token per request; the table
    // lookup must never touch the heap (no lowercasing into a String).
    let tokens = [
        "euclidean", "eucl-lb", "arccos", "ARCCOS-FAST", "mult", "lb1", "MULT-LB2", "Ptolemaic",
        "ptol-fast", "auto", "not-a-bound",
    ];
    let mut hits = 0usize;
    let allocs = count_allocs(|| {
        for _ in 0..64 {
            for t in tokens {
                if BoundKind::parse(t).is_some() {
                    hits += 1;
                }
            }
        }
    });
    assert_eq!(hits, 64 * (tokens.len() - 1));
    assert_eq!(allocs, 0, "BoundKind::parse allocated {allocs} times");
}

#[test]
fn steady_state_obs_recording_allocates_nothing() {
    // Aggregate observability on (ADR-007): the per-context bound-slack
    // window, its drain into the global registry, and the kernel-scan span
    // timings all write fixed-capacity structures — the zero-allocation
    // bar of the tracing-off serving path is unchanged with observability
    // enabled.
    for kernel in ALL_KERNELS {
        let store = uniform_sphere_store(2048, 32, 17).with_kernel(kernel);
        let queries: Vec<DenseVec> = (0..6usize).map(|i| store.vec(i * 311)).collect();
        for kind in ALL_KINDS {
            let index = kind.build(store.view(), BoundKind::Mult);
            let mut ctx = QueryContext::new();
            ctx.set_obs_enabled(true);
            let mut out = Vec::new();
            let mut run = |ctx: &mut QueryContext, out: &mut Vec<(u32, f64)>| {
                for q in &queries {
                    ctx.begin_query();
                    index.knn_into(q, 10, ctx, out);
                    ctx.begin_query();
                    index.range_into(q, 0.2, ctx, out);
                }
                ctx.drain_slack(kind.ordinal());
            };
            run(&mut ctx, &mut out);
            run(&mut ctx, &mut out);
            let allocs = count_allocs(|| run(&mut ctx, &mut out));
            assert_eq!(
                allocs,
                0,
                "obs-enabled steady state {} / {} allocated {} times per 12 queries",
                kind.name(),
                kernel.name(),
                allocs
            );
        }
    }
}

// --- 4. one QuantQuery build per query -------------------------------------

#[test]
fn quantized_traversal_builds_one_quant_query_per_query() {
    let store = uniform_sphere_store(2048, 16, 21).with_kernel(KernelKind::QuantizedI8);
    assert!(store.quant_sidecar().is_some());
    // Small leaves => many bucket scans per traversal.
    let tree = simetra::index::VpTree::with_leaf_size(store.view(), BoundKind::Mult, 5, 8);
    let queries: Vec<DenseVec> = uniform_sphere(6, 16, 22);
    let mut ctx = QueryContext::new();
    // tau -1.0: every leaf bucket of every traversal is scanned.
    let results = tree.range_batch(&queries, -1.0, &mut ctx);
    assert_eq!(results.len(), 6);
    for (hits, _) in &results {
        assert_eq!(hits.len(), 2048, "tau=-1 returns the whole corpus");
    }
    assert_eq!(
        ctx.quant_builds(),
        6,
        "one QuantQuery build per query, independent of leaf-bucket count"
    );
    // The pre-filter really ran (scan calls far outnumber the 6 builds).
    assert!(store.kernel().counters().quant_prefilter_rows() > 0);
}
