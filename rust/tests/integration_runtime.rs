//! Integration: PJRT runtime executing the real AOT artifacts, cross-checked
//! against native rust scoring. Requires `make artifacts` (skipped with a
//! note when artifacts/ is absent, e.g. in a fresh checkout).

use simetra::data::uniform_sphere;
use simetra::metrics::SimVector;
use simetra::runtime::{Engine, EngineHandle};

fn artifact_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: no artifacts/ (run `make artifacts`)");
        None
    }
}

/// Artifacts present AND an engine that can execute them: on the default
/// (non-`pjrt`) build `Engine::load` is the always-erroring stub, which must
/// skip these tests, not fail them. Any *other* load error on a real
/// `pjrt` build is a regression and still fails loudly.
fn load_engine() -> Option<Engine> {
    let dir = artifact_dir()?;
    match Engine::load(&dir) {
        Ok(engine) => Some(engine),
        Err(e) if e.to_string().contains("pjrt") => {
            eprintln!("skipping: {e}");
            None
        }
        Err(e) => panic!("engine failed to load real artifacts: {e}"),
    }
}

#[test]
fn engine_loads_and_reports_platform() {
    let Some(engine) = load_engine() else { return };
    assert_eq!(engine.platform().to_lowercase(), "cpu");
    assert!(engine.manifest().artifacts.len() >= 3);
}

#[test]
fn score_topk_matches_native_scoring() {
    let Some(engine) = load_engine() else { return };
    let corpus = uniform_sphere(1000, 128, 21);
    let queries = uniform_sphere(8, 128, 22);
    let qflat: Vec<f32> = queries.iter().flat_map(|q| q.as_slice().to_vec()).collect();
    let cflat: Vec<f32> = corpus.iter().flat_map(|c| c.as_slice().to_vec()).collect();
    let out = engine.score_topk(&qflat, 8, &cflat, 1000, 128, 10).unwrap();
    assert_eq!(out.k, 10);
    for (qi, q) in queries.iter().enumerate() {
        let mut native: Vec<(usize, f64)> =
            corpus.iter().enumerate().map(|(i, c)| (i, q.sim(c))).collect();
        native.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        for j in 0..10 {
            let got_v = out.values[qi * 10 + j] as f64;
            let want_v = native[j].1;
            assert!(
                (got_v - want_v).abs() < 1e-4,
                "q{qi} rank{j}: got {got_v} want {want_v}"
            );
        }
        // Indices must point at rows that actually score their value.
        for j in 0..10 {
            let idx = out.indices[qi * 10 + j] as usize;
            let v = out.values[qi * 10 + j] as f64;
            assert!((q.sim(&corpus[idx]) - v).abs() < 1e-4);
        }
    }
}

#[test]
fn score_topk_respects_valid_n_masking() {
    // Ask for a corpus smaller than the artifact tile: padded rows must
    // never appear among the results.
    let Some(engine) = load_engine() else { return };
    let corpus = uniform_sphere(300, 128, 23);
    let queries = uniform_sphere(4, 128, 24);
    let qflat: Vec<f32> = queries.iter().flat_map(|q| q.as_slice().to_vec()).collect();
    let cflat: Vec<f32> = corpus.iter().flat_map(|c| c.as_slice().to_vec()).collect();
    let out = engine.score_topk(&qflat, 4, &cflat, 300, 128, 16).unwrap();
    for &idx in &out.indices {
        assert!((idx as usize) < 300, "padded index {idx} leaked");
    }
}

#[test]
fn score_topk_pads_smaller_d() {
    // d=64 < artifact d=128: zero-padding features preserves cosine.
    let Some(engine) = load_engine() else { return };
    let corpus = uniform_sphere(500, 64, 25);
    let queries = uniform_sphere(4, 64, 26);
    let qflat: Vec<f32> = queries.iter().flat_map(|q| q.as_slice().to_vec()).collect();
    let cflat: Vec<f32> = corpus.iter().flat_map(|c| c.as_slice().to_vec()).collect();
    let out = engine.score_topk(&qflat, 4, &cflat, 500, 64, 5).unwrap();
    for (qi, q) in queries.iter().enumerate() {
        let best = corpus
            .iter()
            .map(|c| q.sim(c))
            .fold(f64::NEG_INFINITY, f64::max);
        assert!((out.values[qi * 5] as f64 - best).abs() < 1e-4);
    }
}

#[test]
fn pivot_filter_intervals_contain_truth() {
    let Some(engine) = load_engine() else { return };
    let corpus = uniform_sphere(800, 64, 27);
    let pivots = uniform_sphere(16, 64, 28);
    let queries = uniform_sphere(8, 64, 29);
    let sim_qp: Vec<f32> = queries
        .iter()
        .flat_map(|q| pivots.iter().map(|p| q.sim(p) as f32).collect::<Vec<_>>())
        .collect();
    let sim_pc: Vec<f32> = pivots
        .iter()
        .flat_map(|p| corpus.iter().map(|c| p.sim(c) as f32).collect::<Vec<_>>())
        .collect();
    let out = engine.pivot_filter(&sim_qp, 8, &sim_pc, 16, 800).unwrap();
    for (qi, q) in queries.iter().enumerate() {
        for (ci, c) in corpus.iter().enumerate() {
            let truth = q.sim(c);
            let lb = out.lb[qi * 800 + ci] as f64;
            let ub = out.ub[qi * 800 + ci] as f64;
            assert!(lb - 1e-4 <= truth, "lb {lb} > truth {truth}");
            assert!(ub + 1e-4 >= truth, "ub {ub} < truth {truth}");
        }
    }
}

#[test]
fn engine_handle_serves_concurrent_callers() {
    let Some(dir) = artifact_dir() else { return };
    let handle = match EngineHandle::spawn(&dir) {
        Ok(h) => std::sync::Arc::new(h),
        Err(e) if e.to_string().contains("pjrt") => {
            eprintln!("skipping: {e}");
            return;
        }
        Err(e) => panic!("engine handle failed to spawn: {e}"),
    };
    let corpus = uniform_sphere(256, 128, 30);
    // All callers share one store; each request ships a zero-copy view.
    let store = simetra::storage::CorpusStore::from_rows(corpus.clone());
    let mut threads = Vec::new();
    for t in 0..4u64 {
        let handle = handle.clone();
        let view = store.view();
        let corpus = corpus.clone();
        threads.push(std::thread::spawn(move || {
            let queries = uniform_sphere(2, 128, 100 + t);
            let qflat: Vec<f32> =
                queries.iter().flat_map(|q| q.as_slice().to_vec()).collect();
            let out = handle
                .score_topk(std::sync::Arc::new(qflat), 2, view, 3)
                .unwrap();
            for (qi, q) in queries.iter().enumerate() {
                let best =
                    corpus.iter().map(|c| q.sim(c)).fold(f64::NEG_INFINITY, f64::max);
                assert!((out.values[qi * 3] as f64 - best).abs() < 1e-4);
            }
        }));
    }
    for t in threads {
        t.join().unwrap();
    }
}

#[test]
fn errors_are_reported_not_panicked() {
    let Some(engine) = load_engine() else { return };
    // Oversized request: no artifact fits.
    let big_q = vec![0.0; 128 * 128];
    let one_row = vec![0.0; 128];
    let err = engine.score_topk(&big_q, 128, &one_row, 1, 128, 5);
    assert!(err.is_err());
    // Shape mismatch.
    let short_q = vec![0.0; 10];
    let err = engine.score_topk(&short_q, 4, &one_row, 1, 128, 5);
    assert!(err.is_err());
}
