//! The one-search-surface contracts (ADR-005):
//!
//!  1. `search(Knn)` is bitwise-equal to the legacy `knn` path across all
//!     7 indexes × 3 kernels × static / sharded / mutable corpora (the
//!     legacy entry points are shims over `search_into`, and both must be
//!     byte-identical to the pre-redesign results the exactness suite
//!     pins to the linear scan).
//!  2. `KnnWithin { k, tau }` equals post-filtered `Knn { k }`, bitwise.
//!  3. A filtered search never spends an exact evaluation on a denied row
//!     (kernel counters prove it) and equals the brute-force oracle over
//!     the admitted subset.
//!  4. A `sim_evals` budget always sets the `truncated` flag when it
//!     stops a traversal, and the partial result is exact over the
//!     evaluated subset; a generous budget changes nothing.
//!  5. Steady-state `search_into` calls — plain, within, and filtered —
//!     allocate zero heap memory (counting global allocator).
//!  6. The wire `search` op round-trips and serves results byte-identical
//!     to the legacy `knn`/`range` ops; typed error codes come back on
//!     the error envelope.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use simetra::bounds::BoundKind;
use simetra::coordinator::router::build_shards;
use simetra::coordinator::{
    server, Coordinator, CoordinatorConfig, IndexKind, Request, Response,
};
use simetra::data::{uniform_sphere, uniform_sphere_store};
use simetra::index::{LinearScan, QueryStats, SimilarityIndex};
use simetra::ingest::{IngestConfig, IngestCorpus};
use simetra::metrics::DenseVec;
use simetra::query::{QueryContext, SearchRequest};
use simetra::storage::{CorpusStore, KernelKind};

// --- counting allocator (thread-local; see integration_query.rs) -----------

thread_local! {
    static COUNTING: Cell<bool> = const { Cell::new(false) };
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

impl CountingAlloc {
    fn note(&self) {
        let _ = COUNTING.try_with(|c| {
            if c.get() {
                let _ = ALLOCS.try_with(|a| a.set(a.get() + 1));
            }
        });
    }
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        self.note();
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        self.note();
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        self.note();
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn count_allocs(f: impl FnOnce()) -> u64 {
    COUNTING.with(|c| c.set(true));
    ALLOCS.with(|a| a.set(0));
    f();
    COUNTING.with(|c| c.set(false));
    ALLOCS.with(|a| a.get())
}

// --- helpers ---------------------------------------------------------------

const ALL_KINDS: [IndexKind; 7] = [
    IndexKind::Linear,
    IndexKind::Vp,
    IndexKind::Ball,
    IndexKind::MTree,
    IndexKind::Cover,
    IndexKind::Laesa,
    IndexKind::Gnat,
];

const ALL_KERNELS: [KernelKind; 3] =
    [KernelKind::Scalar, KernelKind::Simd, KernelKind::QuantizedI8];

fn assert_bits_eq(a: &[(u32, f64)], b: &[(u32, f64)], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: lengths differ ({} vs {})", a.len(), b.len());
    for (pos, ((ia, sa), (ib, sb))) in a.iter().zip(b).enumerate() {
        assert_eq!(ia, ib, "{what}: id at {pos}");
        assert_eq!(sa.to_bits(), sb.to_bits(), "{what}: sim bits at {pos}");
    }
}

// --- 1. search(Knn/Range) == legacy knn/range, every index × kernel --------

#[test]
fn search_matches_legacy_bitwise_across_indexes_and_kernels() {
    let rows = uniform_sphere(1200, 16, 4242);
    let queries: Vec<DenseVec> = uniform_sphere(6, 16, 4243);
    for kernel in ALL_KERNELS {
        let store = CorpusStore::from_rows(rows.clone()).with_kernel(kernel);
        for kind in ALL_KINDS {
            let index = kind.build(store.view(), BoundKind::Mult);
            let what = format!("{} / {}", kind.name(), kernel.name());
            for q in &queries {
                let mut st = QueryStats::default();
                let legacy = index.knn(q, 9, &mut st);
                let resp = index.search(q, &SearchRequest::knn(9).build());
                assert_bits_eq(&legacy, &resp.hits, &format!("{what} knn"));
                assert!(!resp.truncated);
                assert_eq!(st.sim_evals, resp.stats.sim_evals, "{what} knn evals");

                let legacy = index.range(q, 0.2, &mut st);
                let resp = index.search(q, &SearchRequest::range(0.2).build());
                assert_bits_eq(&legacy, &resp.hits, &format!("{what} range"));
            }
        }
    }
}

#[test]
fn sharded_and_mutable_search_matches_legacy() {
    // Sharded: every shard answers search(Knn) == knn_ctx bitwise.
    let store = uniform_sphere_store(900, 12, 77);
    let shards = build_shards(&store, 3, IndexKind::Vp, BoundKind::Mult, 0);
    let queries: Vec<DenseVec> = uniform_sphere(4, 12, 78);
    for shard in &shards {
        let mut ctx = QueryContext::new();
        for q in &queries {
            let (legacy, _) = shard.knn_ctx(q, 5, &mut ctx);
            let req = SearchRequest::knn(5).build();
            let (hits, _, truncated) = shard.search_ctx(q, &req, &mut ctx);
            assert_bits_eq(&legacy, &hits, "shard knn");
            assert!(!truncated);
        }
    }

    // Mutable: search over the generation fan-out == legacy knn/range.
    let cfg = IngestConfig { seal_threshold: 300, background: false, ..IngestConfig::new(12) };
    let corpus = IngestCorpus::new(cfg).unwrap();
    let rows = uniform_sphere(700, 12, 79);
    for r in &rows {
        corpus.insert(r.as_slice().to_vec()).unwrap();
    }
    for id in (0..700u64).step_by(111) {
        assert!(corpus.delete(id));
    }
    let mut ctx = QueryContext::new();
    let mut legacy = Vec::new();
    let mut new = Vec::new();
    for q in &queries {
        let e1 = corpus.knn_ctx(q, 8, &mut ctx, &mut legacy);
        let (e2, truncated) =
            corpus.search_ctx(q, &SearchRequest::knn(8).build(), &mut ctx, &mut new);
        assert_eq!(legacy, new, "mutable knn");
        assert_eq!(e1, e2);
        assert!(!truncated);

        let e1 = corpus.range_ctx(q, 0.15, &mut ctx, &mut legacy);
        let (e2, _) = corpus.search_ctx(q, &SearchRequest::range(0.15).build(), &mut ctx, &mut new);
        assert_eq!(legacy, new, "mutable range");
        assert_eq!(e1, e2);
    }
}

// --- 2. KnnWithin == post-filtered Knn -------------------------------------

#[test]
fn knn_within_equals_post_filtered_knn() {
    let rows = uniform_sphere(1200, 16, 555);
    let queries: Vec<DenseVec> = uniform_sphere(5, 16, 556);
    for kernel in [KernelKind::Scalar, KernelKind::QuantizedI8] {
        let store = CorpusStore::from_rows(rows.clone()).with_kernel(kernel);
        for kind in ALL_KINDS {
            let index = kind.build(store.view(), BoundKind::Mult);
            for q in &queries {
                for tau in [-0.5, 0.05, 0.3, 0.99] {
                    let plain = index.search(q, &SearchRequest::knn(10).build());
                    let want: Vec<(u32, f64)> =
                        plain.hits.iter().copied().filter(|&(_, s)| s >= tau).collect();
                    let within = index.search(q, &SearchRequest::knn(10).within(tau).build());
                    assert_bits_eq(
                        &want,
                        &within.hits,
                        &format!("{} / {} tau={tau}", kind.name(), kernel.name()),
                    );
                    // The restricted traversal never spends more.
                    assert!(
                        within.stats.sim_evals <= plain.stats.sim_evals,
                        "{}: within spent more evals than plain knn",
                        kind.name()
                    );
                }
            }
        }
    }
}

// --- 3. filters: denied rows cost nothing, results match the oracle --------

#[test]
fn filtered_search_matches_oracle_and_skips_denied_rows() {
    let rows = uniform_sphere(1500, 12, 91);
    let queries: Vec<DenseVec> = uniform_sphere(4, 12, 92);
    let allow: Vec<u64> = (0..1500u64).filter(|id| id % 3 == 0).collect();
    let deny: Vec<u64> = (0..1500u64).filter(|id| id % 4 == 1).collect();

    for kernel in ALL_KERNELS {
        let store = CorpusStore::from_rows(rows.clone()).with_kernel(kernel);
        for kind in ALL_KINDS {
            let index = kind.build(store.view(), BoundKind::Mult);
            let what = format!("{} / {}", kind.name(), kernel.name());
            for q in &queries {
                // Oracle: exhaustive scan post-filtered to the admitted set.
                let full = index.search(q, &SearchRequest::knn(1500).build());
                let top_allowed = |admit: &dyn Fn(u64) -> bool, k: usize| -> Vec<(u32, f64)> {
                    full.hits
                        .iter()
                        .copied()
                        .filter(|&(id, _)| admit(id as u64))
                        .take(k)
                        .collect()
                };
                let admit_allow = |id: u64| allow.binary_search(&id).is_ok();
                let admit_deny = |id: u64| deny.binary_search(&id).is_err();

                let got = index.search(q, &SearchRequest::knn(7).allow(allow.clone()).build());
                assert_bits_eq(&top_allowed(&admit_allow, 7), &got.hits, &format!("{what} allow"));

                let got = index.search(q, &SearchRequest::knn(7).deny(deny.clone()).build());
                assert_bits_eq(&top_allowed(&admit_deny, 7), &got.hits, &format!("{what} deny"));

                // Range under a deny filter: no denied id ever surfaces.
                let got = index.search(q, &SearchRequest::range(0.1).deny(deny.clone()).build());
                assert!(
                    got.hits.iter().all(|&(id, _)| admit_deny(id as u64)),
                    "{what}: denied id in range results"
                );
            }
        }
    }
}

#[test]
fn filtered_linear_scan_never_evaluates_denied_rows_counter_asserted() {
    // LinearScan evaluates exactly the admitted rows — provable from the
    // kernel's own counters (blocked_scan_rows counts rows that reached
    // an exact evaluation) and from the per-query eval count.
    let store = uniform_sphere_store(2000, 8, 93);
    let index = LinearScan::build(store.view());
    let q = store.vec(0);
    let allow: Vec<u64> = (0..2000u64).filter(|id| id % 10 == 0).collect(); // 200 rows

    let before = store.kernel().counters().blocked_scan_rows();
    let resp = index.search(&q, &SearchRequest::knn(5).allow(allow.clone()).build());
    let after = store.kernel().counters().blocked_scan_rows();

    assert_eq!(resp.stats.sim_evals, allow.len() as u64, "evals != admitted rows");
    assert_eq!(after - before, allow.len() as u64, "kernel scanned a denied row");
    assert!(resp.hits.iter().all(|&(id, _)| id % 10 == 0));

    // Same through the i8 pre-filter: denied rows neither pre-filtered
    // nor re-ranked.
    let store = uniform_sphere_store(2000, 8, 93).with_kernel(KernelKind::QuantizedI8);
    assert!(store.quant_sidecar().is_some());
    let index = LinearScan::build(store.view());
    let before = store.kernel().counters().quant_prefilter_rows();
    let resp = index.search(&q, &SearchRequest::knn(5).allow(allow.clone()).build());
    let after = store.kernel().counters().quant_prefilter_rows();
    assert_eq!(after - before, allow.len() as u64, "i8 pre-filtered a denied row");
    assert!(resp.stats.sim_evals <= allow.len() as u64);
}

// --- 4. budgets ------------------------------------------------------------

#[test]
fn budget_truncation_always_sets_the_flag() {
    let store = uniform_sphere_store(2000, 8, 94);
    let q = store.vec(17);
    for kind in ALL_KINDS {
        let index = kind.build(store.view(), BoundKind::Mult);
        let free = index.search(&q, &SearchRequest::knn(10).build());
        assert!(!free.truncated, "{}: unbudgeted search claimed truncation", kind.name());

        // A generous budget changes nothing.
        let roomy = index.search(&q, &SearchRequest::knn(10).budget(1_000_000).build());
        assert!(!roomy.truncated, "{}", kind.name());
        assert_bits_eq(&free.hits, &roomy.hits, &format!("{} roomy budget", kind.name()));

        // A starving budget must truncate (every index spends >= 1 eval
        // per item it returns, so 3 evals cannot finish 2000 rows).
        let starved = index.search(&q, &SearchRequest::knn(10).budget(3).build());
        assert!(starved.truncated, "{}: budget 3 did not truncate", kind.name());
        assert!(
            starved.stats.sim_evals < free.stats.sim_evals,
            "{}: budget did not reduce work",
            kind.name()
        );
    }
}

#[test]
fn budgeted_partial_results_are_exact_over_the_evaluated_subset() {
    // Linear scans chunk deterministically front-to-back, so a budget of
    // ~b rows returns the true top-k of the first ceil(b/1024)*1024 rows.
    let store = uniform_sphere_store(4096, 8, 95);
    let q = store.vec(1);
    let index = LinearScan::build(store.view());
    let resp = index.search(&q, &SearchRequest::knn(5).budget(2048).build());
    assert!(resp.truncated);
    assert_eq!(resp.stats.sim_evals, 2048);
    let prefix = LinearScan::build(store.slice(0..2048));
    let mut st = QueryStats::default();
    let want = prefix.knn(&q, 5, &mut st);
    assert_bits_eq(&want, &resp.hits, "budgeted linear prefix");
}

#[test]
fn budget_truncates_mutable_corpora_including_the_memtable() {
    // Regression: the memtable path must honor the budget even though
    // each generation's search_into disarms the plan at its exit — and
    // a memtable-only corpus (nothing sealed yet) must truncate too.
    let cfg = IngestConfig { seal_threshold: 100_000, background: false, ..IngestConfig::new(8) };
    let corpus = IngestCorpus::new(cfg).unwrap();
    let rows = uniform_sphere(3000, 8, 101);
    for r in &rows {
        corpus.insert(r.as_slice().to_vec()).unwrap();
    }
    assert_eq!(corpus.stats().generations, 0, "memtable-only by construction");
    let mut ctx = QueryContext::new();
    let mut out = Vec::new();
    let (evals, truncated) =
        corpus.search_ctx(&rows[0], &SearchRequest::knn(5).budget(3).build(), &mut ctx, &mut out);
    assert!(truncated, "memtable-only budget ignored");
    assert!(evals < 3000, "budget did not reduce memtable work (spent {evals})");

    // Sealed generations + staged memtable: still truncates, still exact
    // over what was evaluated; a generous budget changes nothing.
    corpus.flush();
    for r in &rows[..50] {
        corpus.insert(r.as_slice().to_vec()).unwrap();
    }
    let (_, truncated) =
        corpus.search_ctx(&rows[1], &SearchRequest::knn(5).budget(3).build(), &mut ctx, &mut out);
    assert!(truncated);
    let mut free = Vec::new();
    corpus.knn_ctx(&rows[1], 5, &mut ctx, &mut free);
    let (_, truncated) = corpus.search_ctx(
        &rows[1],
        &SearchRequest::knn(5).budget(10_000_000).build(),
        &mut ctx,
        &mut out,
    );
    assert!(!truncated);
    assert_eq!(out, free, "roomy budget changed mutable results");
}

// --- 5. zero allocations in the steady state -------------------------------

#[test]
fn steady_state_search_allocates_nothing() {
    let store = uniform_sphere_store(2048, 16, 96);
    let allow: Vec<u64> = (0..2048u64).step_by(2).collect();
    let reqs = [
        SearchRequest::knn(10).build(),
        SearchRequest::range(0.2).build(),
        SearchRequest::knn(10).within(0.1).build(),
        SearchRequest::knn(10).allow(allow).build(),
    ];
    let queries: Vec<DenseVec> = (0..4usize).map(|i| store.vec(i * 500)).collect();
    for kind in ALL_KINDS {
        let index = kind.build(store.view(), BoundKind::Mult);
        let mut ctx = QueryContext::new();
        let mut resp = simetra::query::SearchResponse::default();
        let mut run = |ctx: &mut QueryContext, resp: &mut simetra::query::SearchResponse| {
            for q in &queries {
                for req in &reqs {
                    ctx.begin_query();
                    index.search_into(q, req, ctx, resp);
                }
            }
        };
        run(&mut ctx, &mut resp);
        run(&mut ctx, &mut resp);
        let allocs = count_allocs(|| run(&mut ctx, &mut resp));
        assert_eq!(allocs, 0, "steady-state {} allocated {} times", kind.name(), allocs);
    }
}

// --- 6. wire surface -------------------------------------------------------

#[test]
fn wire_search_serves_byte_identical_results_to_legacy_ops() {
    let pts = uniform_sphere(400, 8, 97);
    let coord = Coordinator::new(
        pts.clone(),
        CoordinatorConfig { n_shards: 2, ..CoordinatorConfig::default() },
    )
    .unwrap();
    let handle = server::serve(coord, "127.0.0.1:0").unwrap();
    let mut client = server::Client::connect(handle.addr()).unwrap();

    for qi in [0usize, 123, 399] {
        let v = pts[qi].as_slice().to_vec();
        // Legacy knn op vs search op with a plain knn plan: same bytes.
        let legacy = client.knn(v.clone(), 6).unwrap();
        let new = client.search(v.clone(), SearchRequest::knn(6).build()).unwrap();
        assert_eq!(legacy.len(), new.hits.len());
        for (a, b) in legacy.iter().zip(&new.hits) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.score.to_bits(), b.score.to_bits());
        }
        assert!(!new.truncated);
        assert!(new.nodes_visited > 0);

        // Legacy range op vs plain range plan.
        let range_req = Request::Range { vector: v.clone(), tau: 0.3 };
        let legacy = match client.request(&range_req).unwrap() {
            Response::Ok { hits, .. } => hits,
            other => panic!("{other:?}"),
        };
        let new = client.search(v.clone(), SearchRequest::range(0.3).build()).unwrap();
        assert_eq!(legacy, new.hits);

        // KnnWithin over the wire == post-filtered knn.
        let within = client.search(v.clone(), SearchRequest::knn(6).within(0.3).build()).unwrap();
        let want: Vec<_> = new.hits.iter().filter(|h| h.score >= 0.3).take(6).collect();
        assert_eq!(within.hits.len(), want.len());

        // Budgeted search over the wire reports truncation.
        let starved = client.search(v.clone(), SearchRequest::knn(6).budget(1).build()).unwrap();
        assert!(starved.truncated);

        // Filtered search over the wire never returns a denied id.
        let deny: Vec<u64> = (0..400).step_by(2).collect();
        let filtered = client.search(v, SearchRequest::knn(6).deny(deny).build()).unwrap();
        assert!(filtered.hits.iter().all(|h| h.id % 2 == 1));
    }
}

#[test]
fn wire_errors_carry_typed_codes() {
    let pts = uniform_sphere(100, 8, 98);
    let coord = Coordinator::new(pts, CoordinatorConfig::default()).unwrap();
    let handle = server::serve(coord, "127.0.0.1:0").unwrap();
    let mut client = server::Client::connect(handle.addr()).unwrap();

    // Wrong dimension -> dim_mismatch, faithfully reconstructed client
    // side (structured fields rebuilt from the stable wire message).
    let err = client
        .search_checked(vec![1.0; 3], SearchRequest::knn(3).build())
        .unwrap_err();
    assert_eq!(err.code(), "dim_mismatch");
    assert_eq!(err, simetra::SimetraError::DimMismatch { got: 3, want: 8 });
    assert!(err.to_string().contains("dimension"));
    match client
        .request(&Request::Search { vector: vec![1.0; 3], req: SearchRequest::knn(3).build() })
        .unwrap()
    {
        Response::Error { code, message } => {
            assert_eq!(code, "dim_mismatch");
            assert!(message.contains("dimension"));
        }
        other => panic!("{other:?}"),
    }

    // i8 kernel override on a scalar-serving corpus -> kernel_unavailable
    // (the corpus carries no quantized sidecar).
    match client
        .request(&Request::Search {
            vector: vec![0.0; 8],
            req: SearchRequest::knn(3).kernel(KernelKind::QuantizedI8).build(),
        })
        .unwrap()
    {
        Response::Error { code, .. } => assert_eq!(code, "kernel_unavailable"),
        // Under SIMETRA_KERNEL=i8 the corpus *does* carry a sidecar and
        // the override is legitimately available.
        Response::Search(_) => {
            assert_eq!(simetra::storage::default_kernel(), KernelKind::QuantizedI8)
        }
        other => panic!("{other:?}"),
    }

    // k = 0 -> bad_request.
    match client
        .request(&Request::Search { vector: vec![0.0; 8], req: SearchRequest::knn(0).build() })
        .unwrap()
    {
        Response::Error { code, .. } => assert_eq!(code, "bad_request"),
        other => panic!("{other:?}"),
    }

    // Unknown op -> unknown_op.
    match client.request_raw(b"{\"op\": \"teleport\"}\n").unwrap() {
        Response::Error { code, .. } => assert_eq!(code, "unknown_op"),
        other => panic!("{other:?}"),
    }

    // Filter ids a JSON double cannot carry exactly are rejected client
    // side instead of silently rounding to a neighboring id.
    let huge = (1u64 << 53) + 2;
    let err = client
        .search(vec![0.0; 8], SearchRequest::knn(3).deny(vec![huge]).build())
        .unwrap_err();
    assert!(err.to_string().contains("2^53"), "{err}");
}

#[test]
fn bound_and_kernel_overrides_return_identical_results() {
    // Every bound is exact; kernels are byte-identical: overrides may only
    // change evaluation counts, never results.
    let store = uniform_sphere_store(1100, 8, 99);
    let q = store.vec(3);
    for kind in [IndexKind::Vp, IndexKind::MTree, IndexKind::Laesa] {
        let index = kind.build(store.view(), BoundKind::Mult);
        let base = index.search(&q, &SearchRequest::knn(8).build());
        for bound in BoundKind::ALL {
            let got = index.search(&q, &SearchRequest::knn(8).bound(bound).build());
            assert_bits_eq(
                &base.hits,
                &got.hits,
                &format!("{} bound={}", kind.name(), bound.name()),
            );
        }
        for kernel in [KernelKind::Scalar, KernelKind::Simd] {
            let got = index.search(&q, &SearchRequest::knn(8).kernel(kernel).build());
            assert_bits_eq(
                &base.hits,
                &got.hits,
                &format!("{} kernel={}", kind.name(), kernel.name()),
            );
        }
    }
}
