//! Storage-backbone guarantees, end to end:
//!
//! 1. Every index built from the same `CorpusStore` view returns
//!    **byte-identical** results to the linear scan over that view — not
//!    just equal-up-to-ties. This works because every scoring path (scalar
//!    `dot_slice`, blocked kernels, per-item `DenseVec::dot`) reduces a
//!    `(query, row)` pair in the same operation order, and the kNN heap
//!    breaks similarity ties by ascending id regardless of insertion order.
//!    Scope: holds on tie-free corpora (continuous random data, as swept
//!    here). With exact f64 similarity ties — duplicate rows — an index may
//!    prune a subtree whose upper bound equals the kNN floor, so results
//!    are exact only up to tie membership (the general contract in
//!    `index/mod.rs`; `degenerate_corpora` in the exactness suite covers it).
//! 2. Shards alias the store's buffer (pointer-equal slices) instead of
//!    copying it: one allocation per served corpus, no matter how many
//!    shards and indexes sit on top.

use simetra::bounds::BoundKind;
use simetra::coordinator::router::build_shards;
use simetra::coordinator::IndexKind;
use simetra::data::{uniform_sphere, uniform_sphere_store, vmf_mixture_store, VmfSpec};
use simetra::index::{
    BallTree, CoverTree, Gnat, Laesa, LinearScan, MTree, QueryStats, SimilarityIndex, VpTree,
};
use simetra::metrics::DenseVec;
use simetra::storage::{CorpusStore, CorpusView};
use simetra::util::Rng;

fn build_all_on_view(
    view: &CorpusView,
    bound: BoundKind,
) -> Vec<Box<dyn SimilarityIndex<DenseVec>>> {
    vec![
        Box::new(VpTree::build(view.clone(), bound, 97)),
        Box::new(BallTree::build(view.clone(), bound, 8)),
        Box::new(MTree::build(view.clone(), bound, 8)),
        Box::new(CoverTree::build(view.clone(), bound)),
        Box::new(Laesa::build(view.clone(), bound, 12)),
        Box::new(Gnat::build(view.clone(), bound, 6)),
    ]
}

/// Randomized sweep (hand-rolled property test; the offline build has no
/// proptest): random corpus shapes, bounds, taus and ks — view-built
/// indexes must agree with the view-built linear scan byte-for-byte.
#[test]
fn view_built_indexes_match_linear_byte_identical() {
    let mut rng = Rng::seed_from_u64(2026);
    for trial in 0..6u64 {
        let n = 60 + rng.below(300);
        let d = 2 + rng.below(40);
        let store = if trial % 2 == 0 {
            uniform_sphere_store(n, d, 9000 + trial)
        } else {
            vmf_mixture_store(&VmfSpec {
                n,
                dim: d,
                clusters: 1 + rng.below(8),
                kappa: rng.uniform(0.0, 120.0),
                seed: 9100 + trial,
            })
            .0
        };
        let view = store.view();
        let lin = LinearScan::build(view.clone());
        let bound = BoundKind::ALL[rng.below(BoundKind::ALL.len())];
        let ctx = format!("trial={trial} n={n} d={d} bound={}", bound.name());
        let out_of_corpus = uniform_sphere(2, d, 9900 + trial);
        for idx in build_all_on_view(&view, bound) {
            for probe in 0..4 {
                let q = if probe < 2 {
                    store.vec(rng.below(n))
                } else {
                    out_of_corpus[probe - 2].clone()
                };
                let tau = rng.uniform(-0.5, 0.95);
                let mut s1 = QueryStats::default();
                let mut s2 = QueryStats::default();
                assert_eq!(
                    idx.range(&q, tau, &mut s1),
                    lin.range(&q, tau, &mut s2),
                    "range mismatch: {ctx} tau={tau} index={}",
                    idx.name()
                );
                let k = 1 + rng.below(15);
                assert_eq!(
                    idx.knn(&q, k, &mut s1),
                    lin.knn(&q, k, &mut s2),
                    "knn mismatch: {ctx} k={k} index={}",
                    idx.name()
                );
            }
        }
    }
}

/// View-built indexes must also agree byte-for-byte with indexes built the
/// old way, from owned `Vec<DenseVec>` clones of the same rows.
#[test]
fn view_built_matches_vec_built() {
    let store = uniform_sphere_store(250, 12, 77);
    let rows: Vec<DenseVec> = (0..store.len()).map(|i| store.vec(i)).collect();
    let view_idx = VpTree::build(store.view(), BoundKind::Mult, 5);
    let vec_idx = VpTree::build(rows.clone(), BoundKind::Mult, 5);
    for qi in [0usize, 100, 249] {
        let q = &rows[qi];
        let mut s1 = QueryStats::default();
        let mut s2 = QueryStats::default();
        assert_eq!(view_idx.range(q, 0.3, &mut s1), vec_idx.range(q, 0.3, &mut s2));
        assert_eq!(view_idx.knn(q, 12, &mut s1), vec_idx.knn(q, 12, &mut s2));
        // Identical trees (same seed, same sims) do identical work.
        assert_eq!(s1, s2);
    }
}

#[test]
fn shard_views_alias_the_store_buffer() {
    let store = uniform_sphere_store(103, 8, 7);
    let d = store.dim();
    let shards = build_shards(&store, 4, IndexKind::Vp, BoundKind::Mult, 8);
    assert_eq!(shards.len(), 4);
    let mut base = 0usize;
    for shard in &shards {
        // Pointer equality: the shard's "matrix" IS a window of the store's
        // one buffer — nothing was copied for the shard, its index, or its
        // pivot table's corpus access.
        assert_eq!(
            shard.flat_corpus().as_ptr(),
            store.flat()[base * d..].as_ptr(),
            "shard at base {base} copied its corpus"
        );
        assert_eq!(shard.flat_corpus().len(), shard.len() * d);
        assert!(std::ptr::eq(
            shard.view().as_contiguous().unwrap(),
            &store.flat()[base * d..(base + shard.len()) * d]
        ));
        base += shard.len();
    }
    assert_eq!(base, 103);
}

#[test]
fn engine_tiles_alias_the_store_buffer() {
    let store = uniform_sphere_store(64, 4, 8);
    let view = store.slice(16..48);
    let tile = view.slice_rows(8, 24);
    // Tiling a shard view for the PJRT engine stays zero-copy.
    assert!(std::ptr::eq(
        tile.as_contiguous().unwrap(),
        &store.flat()[24 * 4..40 * 4]
    ));
}

#[test]
fn store_backed_coordinator_matches_view_linear_scan() {
    use simetra::coordinator::{Coordinator, CoordinatorConfig};
    let store = uniform_sphere_store(400, 16, 55);
    let lin = LinearScan::build(store.view());
    let coord = Coordinator::new(
        store.clone(),
        CoordinatorConfig { n_shards: 3, ..Default::default() },
    )
    .unwrap();
    for qi in [0u32, 199, 399] {
        let q = store.vec(qi as usize);
        let (hits, _) = coord.knn(q.as_slice().to_vec(), 7).unwrap();
        let mut st = QueryStats::default();
        let want = lin.knn(&q, 7, &mut st);
        assert_eq!(hits.len(), want.len());
        for (h, (id, s)) in hits.iter().zip(&want) {
            assert_eq!(h.id, *id as u64);
            // The coordinator re-normalizes client vectors on ingest, which
            // can perturb an already-unit query by one f32 ulp per lane.
            assert!((h.score - s).abs() < 1e-6);
        }
    }
}
