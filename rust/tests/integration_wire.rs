//! The streaming wire path's conformance and allocation contracts
//! (ADR-008):
//!
//!  1. The worker-pool server answers byte-identically to the legacy
//!     thread-per-connection `Json`-tree server, across the full wire-op
//!     corpus (happy paths, optioned plans, malformed / truncated lines,
//!     read-only mutations) — sequentially and as one pipelined frame.
//!  2. Robustness: a non-UTF-8 frame earns an error line on the pool
//!     server (the legacy server dropped the connection) and the
//!     connection keeps serving afterwards.
//!  3. More concurrent clients than pool workers all get exact answers.
//!  4. The steady-state wire path performs **zero heap allocations** from
//!     request-line parse through response-line serialization on plain
//!     kNN traffic: `parse_wire_streaming` → `DenseVec::refill` →
//!     `knn_into` through a warmed `QueryContext` → `write_response` into
//!     a reused output buffer.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use simetra::bounds::BoundKind;
use simetra::coordinator::protocol::{
    parse_wire_streaming, write_response, Hit, Request, Response, WireOp, WireScratch,
};
use simetra::coordinator::server::{serve, serve_legacy, serve_with, Client, ServeConfig};
use simetra::coordinator::{Coordinator, CoordinatorConfig, IndexKind};
use simetra::data::{uniform_sphere, uniform_sphere_store};
use simetra::metrics::DenseVec;
use simetra::query::QueryContext;

// --- counting allocator ----------------------------------------------------

thread_local! {
    static COUNTING: Cell<bool> = const { Cell::new(false) };
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

/// System allocator that counts allocations made by the *current thread*
/// while that thread has counting enabled — the zero-allocation assertion
/// stays exact even with other tests running in parallel threads.
struct CountingAlloc;

impl CountingAlloc {
    fn note(&self) {
        // try_with: allocation during TLS teardown must not panic.
        let _ = COUNTING.try_with(|c| {
            if c.get() {
                let _ = ALLOCS.try_with(|a| a.set(a.get() + 1));
            }
        });
    }
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        self.note();
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        self.note();
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        self.note();
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn count_allocs(f: impl FnOnce()) -> u64 {
    COUNTING.with(|c| c.set(true));
    ALLOCS.with(|a| a.set(0));
    f();
    COUNTING.with(|c| c.set(false));
    ALLOCS.with(|a| a.get())
}

// --- 1. pool server == legacy server, byte for byte ------------------------

/// Deterministic request corpus: every wire op whose reply does not
/// depend on shared mutable counters (`stats` / `metrics` are excluded —
/// the two servers share one coordinator, so those drift by design).
fn corpus_lines() -> Vec<String> {
    vec![
        r#"{"op":"ping"}"#.into(),
        r#"{"op":"config"}"#.into(),
        r#"{"op":"knn","vector":[1,0,0,0,0,0,0,0],"k":5}"#.into(),
        r#"{"op":"knn","vector":[0.5,-0.5,0,0,0,0,0,0],"k":1}"#.into(),
        r#"{"op":"range","vector":[0,1,0,0,0,0,0,0],"tau":0.8}"#.into(),
        r#"{"op":"search","v":1,"vector":[0,0,1,0,0,0,0,0],"mode":"knn","k":3}"#.into(),
        r#"{"op":"search","v":1,"vector":[0,0,1,0,0,0,0,0],"mode":"range","tau":0.5}"#.into(),
        r#"{"op":"search","v":1,"vector":[1,0,0,0,0,0,0,0],"mode":"knn","k":3,"allow":[2,4,6]}"#
            .into(),
        r#"{"op":"search","v":1,"vector":[1,0,0,0,0,0,0,0],"mode":"knn","k":2,"trace":true}"#
            .into(),
        r#"{"op":"explain","v":1,"vector":[0,1,0,0,0,0,0,0],"mode":"knn","k":2}"#.into(),
        // Errors: unknown op, malformed, truncated, type errors, bad dims,
        // read-only mutations — every reply line must still match.
        r#"{"op":"explode"}"#.into(),
        r#"{not json}"#.into(),
        r#"{"op":"knn","vector":[1,2"#.into(),
        r#"{"op":"knn","vector":"nope","k":1}"#.into(),
        r#"{"op":"knn","vector":[1,0,0,0,0,0,0,0]}"#.into(),
        r#"{"op":"knn","vector":[1,2,3],"k":2}"#.into(),
        r#"{"op":"search","v":2,"vector":[1,0,0,0,0,0,0,0],"mode":"knn","k":1}"#.into(),
        r#"{"op":"delete","id":9007199254740993}"#.into(),
        r#"{"op":"insert","vector":[1,0,0,0,0,0,0,0]}"#.into(),
        r#"{"op":"delete","id":3}"#.into(),
        r#"{"op":"flush"}"#.into(),
        r#"{"op":"compact"}"#.into(),
        r#"{"op":"ping","extra":"ignored"}"#.into(),
    ]
}

fn read_line(reader: &mut BufReader<TcpStream>) -> String {
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    line
}

#[test]
fn pool_server_matches_legacy_server_byte_for_byte() {
    let pts = uniform_sphere(120, 8, 207);
    let coord = Coordinator::new(pts, CoordinatorConfig::default()).unwrap();
    let pool = serve(coord.clone(), "127.0.0.1:0").unwrap();
    let legacy = serve_legacy(coord, "127.0.0.1:0").unwrap();
    let lines = corpus_lines();

    // Sequential: one request/reply round trip at a time on each server.
    let mut ps = TcpStream::connect(pool.addr()).unwrap();
    let mut ls = TcpStream::connect(legacy.addr()).unwrap();
    let mut pr = BufReader::new(ps.try_clone().unwrap());
    let mut lr = BufReader::new(ls.try_clone().unwrap());
    let mut legacy_replies = Vec::new();
    for line in &lines {
        ps.write_all(line.as_bytes()).unwrap();
        ps.write_all(b"\n").unwrap();
        ls.write_all(line.as_bytes()).unwrap();
        ls.write_all(b"\n").unwrap();
        let from_pool = read_line(&mut pr);
        let from_legacy = read_line(&mut lr);
        assert_eq!(from_pool, from_legacy, "divergent replies for {line}");
        assert!(from_pool.ends_with('\n'), "unterminated reply for {line}");
        legacy_replies.push(from_legacy);
    }

    // Pipelined: the whole corpus as one frame into the pool server must
    // produce the same reply lines, in order.
    let mut burst = Vec::new();
    for line in &lines {
        burst.extend_from_slice(line.as_bytes());
        burst.push(b'\n');
    }
    let mut ps2 = TcpStream::connect(pool.addr()).unwrap();
    ps2.write_all(&burst).unwrap();
    let mut pr2 = BufReader::new(ps2);
    for (i, want) in legacy_replies.iter().enumerate() {
        let got = read_line(&mut pr2);
        assert_eq!(&got, want, "pipelined reply {i} diverged ({})", lines[i]);
    }
}

// --- 2. robustness past the legacy server ----------------------------------

#[test]
fn non_utf8_frame_gets_an_error_line_and_the_connection_survives() {
    let pts = uniform_sphere(60, 8, 208);
    let coord = Coordinator::new(pts, CoordinatorConfig::default()).unwrap();
    let pool = serve(coord, "127.0.0.1:0").unwrap();
    let mut stream = TcpStream::connect(pool.addr()).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    stream.write_all(b"{\"op\":\"ping\",\"x\":\"\xff\"}\n").unwrap();
    let reply = read_line(&mut reader);
    match Response::parse(&reply).unwrap() {
        Response::Error { code, .. } => assert_eq!(code, "bad_request"),
        other => panic!("{other:?}"),
    }
    // The same connection still answers.
    stream.write_all(b"{\"op\":\"ping\"}\n").unwrap();
    match Response::parse(&read_line(&mut reader)).unwrap() {
        Response::Pong => {}
        other => panic!("{other:?}"),
    }
}

// --- 3. more clients than workers ------------------------------------------

#[test]
fn exact_answers_with_more_clients_than_workers() {
    let pts = uniform_sphere(90, 8, 209);
    let coord = Coordinator::new(pts.clone(), CoordinatorConfig::default()).unwrap();
    let server = serve_with(coord, "127.0.0.1:0", ServeConfig { workers: 2 }).unwrap();
    let addr = server.addr();
    let mut handles = Vec::new();
    for c in 0..6usize {
        let pts = pts.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(addr).unwrap();
            for qi in 0..8 {
                let id = (c * 17 + qi) % 90;
                let hits = client.knn(pts[id].as_slice().to_vec(), 1).unwrap();
                assert_eq!(hits[0].id, id as u64);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}

// --- 4. zero allocations, request line in to response line out -------------

#[test]
fn steady_state_wire_path_allocates_nothing() {
    let store = uniform_sphere_store(2048, 32, 210);
    let index = IndexKind::Vp.build(store.view(), BoundKind::Mult);
    // Request lines as they arrive off the socket, pre-rendered through
    // the legacy serializer (setup may allocate freely).
    let lines: Vec<String> = (0..8usize)
        .map(|i| {
            let vector = store.vec(i * 251).as_slice().to_vec();
            Request::Knn { vector, k: 10 }.to_json().to_string()
        })
        .collect();

    let mut scratch = WireScratch::new();
    let mut qvec = DenseVec::new(vec![0.0; 32]);
    let mut ctx = QueryContext::new();
    let mut hits: Vec<(u32, f64)> = Vec::new();
    let mut resp = Response::Ok { hits: Vec::new(), sim_evals: 0 };
    let mut out = String::new();

    let mut run = || {
        for line in &lines {
            // Parse straight off the line bytes into connection scratch.
            let op = parse_wire_streaming(line.as_bytes(), &mut scratch).unwrap();
            let k = match op {
                WireOp::Knn { k } => k,
                other => panic!("{other:?}"),
            };
            // Query vector lands in the reused DenseVec, then the warmed
            // QueryContext answers into the reused hit buffer.
            qvec.refill(scratch.vector());
            ctx.begin_query();
            index.knn_into(&qvec, k, &mut ctx, &mut hits);
            // Serialize through the tree-free writer into a reused buffer.
            if let Response::Ok { hits: out_hits, sim_evals } = &mut resp {
                out_hits.clear();
                out_hits.extend(hits.iter().map(|&(id, score)| Hit { id: id as u64, score }));
                *sim_evals = 0;
            }
            out.clear();
            write_response(&resp, &mut out);
            out.push('\n');
            assert!(out.starts_with(r#"{"status":"ok""#), "{out}");
        }
    };

    // Two warm rounds: scratch vector/unescape buffers, the DenseVec
    // payload, context arenas, the hit and response buffers all reach
    // steady-state capacity before the counting round.
    run();
    run();
    let allocs = count_allocs(run);
    assert_eq!(allocs, 0, "wire path allocated {allocs} times over 8 requests");
}
