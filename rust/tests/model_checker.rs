//! Model-checker integration suite (ADR-010): bounded exhaustive schedule
//! exploration of the crate's real concurrency primitives — the
//! hazard-pointer [`SnapshotCell`], the [`ObsRegistry`] slack-drain path,
//! and the server pool's [`RunQueue`] — plus a deliberately broken cell
//! that proves the checker actually catches use-after-free.
//!
//! Every test runs under plain `cargo test`; no nightly toolchain, no
//! external scheduler. The tests are ignored under Miri (each explores
//! thousands of executions, far past Miri's budget; Miri instead runs the
//! `--lib` unit tests of `sync::` and `ingest::swap`).

use std::sync::Arc;
use std::time::Duration;

use simetra::bounds::BoundKind;
use simetra::ingest::swap::SnapshotCell;
use simetra::obs::{ObsRegistry, SlackWindow};
use simetra::sync::model::{self, explore, Config};
use simetra::sync::queue::RunQueue;
use simetra::sync::{AtomicPtr, AtomicU64, Ordering};

/// Condvar poll interval for queue tests. Under the model every
/// `wait_timeout` is a single voluntary yield regardless of duration, so
/// the value only matters for the (non-model) fallback path.
const POLL: Duration = Duration::from_millis(5);

type Body = Box<dyn FnOnce() + Send>;

/// Tentpole scenario: two readers and two writers race on a two-slot
/// `SnapshotCell`. Exhaustively explores the bounded schedule space and
/// asserts no torn publication (readers only ever see fully-written
/// snapshots), no use-after-free / double-reclaim (the swap path's
/// `note_*` hooks feed the checker), and no leaked retirement
/// (allocations and reclamations balance across every execution).
#[test]
#[cfg_attr(miri, ignore)]
fn snapshot_cell_two_readers_two_writers_is_safe() {
    let cfg = Config { max_preemptions: 2, max_steps: 20_000, max_execs: 150_000 };
    let report = explore(cfg, || {
        let cell = Arc::new(SnapshotCell::with_slots(Arc::new(vec![0u64; 4]), 2));
        let mut bodies: Vec<Body> = Vec::new();
        for w in 1..=2u64 {
            let cell = cell.clone();
            bodies.push(Box::new(move || {
                cell.store(Arc::new(vec![w; 4]));
            }));
        }
        for _ in 0..2 {
            let cell = cell.clone();
            bodies.push(Box::new(move || {
                let snap = cell.load();
                let first = snap[0];
                assert!(
                    snap.iter().all(|&x| x == first),
                    "torn publication: {snap:?}"
                );
                assert!(first <= 2, "impossible value: {first}");
            }));
        }
        bodies
    });
    assert!(report.failure.is_none(), "{:?}", report.failure);
    assert!(report.complete, "schedule space not exhausted: {report:?}");
    assert!(report.executions > 1, "expected many interleavings: {report:?}");
    assert!(report.allocs_total > 0, "{report:?}");
    assert_eq!(
        report.allocs_total, report.frees_total,
        "leaked retirements: {report:?}"
    );
}

/// A snapshot cell with the safety net removed: no hazard slots, no
/// publish re-validation — `store` retires the old value immediately.
/// The one-reader/one-writer race is a real use-after-free, and the
/// checker must find it. (The box is intentionally *not* freed when
/// retired, so the failing schedule is caught by the model's books
/// without the test process ever touching dead memory.)
struct BrokenCell {
    current: AtomicPtr<u64>,
}

impl BrokenCell {
    fn new(v: u64) -> BrokenCell {
        let p = Box::into_raw(Box::new(v));
        model::note_alloc(p as usize);
        BrokenCell { current: AtomicPtr::new(p) }
    }

    fn load(&self) -> u64 {
        let p = self.current.load(Ordering::SeqCst);
        model::note_deref(p as usize);
        // SAFETY: unsound by construction — nothing stops a concurrent
        // `store` from retiring `p` between the load above and this
        // dereference. The model checker aborts the failing schedule at
        // `note_deref`, before execution reaches this line; on clean
        // schedules the pointee is still live (retired boxes are leaked,
        // never reused).
        unsafe { *p }
    }

    fn store(&self, v: u64) {
        let fresh = Box::into_raw(Box::new(v));
        model::note_alloc(fresh as usize);
        let old = self.current.swap(fresh, Ordering::SeqCst);
        // Retire immediately — the bug under test. The box itself is
        // leaked (see the type-level comment) so a racing reader's
        // real dereference stays within live memory.
        model::note_free(old as usize);
    }
}

impl Drop for BrokenCell {
    fn drop(&mut self) {
        let p = *self.current.get_mut();
        model::note_free(p as usize);
        // SAFETY: `&mut self` — no concurrent reader can hold `p`, and
        // the current pointer is never retired by `store`, so this is the
        // box's first and only reclamation.
        unsafe { drop(Box::from_raw(p)) };
    }
}

/// Negative control: the checker must catch the use-after-free a
/// hazard-free cell permits. Guards against the model silently passing
/// everything (e.g. schedule points not firing, hooks disconnected).
#[test]
#[cfg_attr(miri, ignore)]
fn model_catches_use_after_free_without_hazard_pointers() {
    let cfg = Config { max_preemptions: 2, max_steps: 5_000, max_execs: 50_000 };
    let report = explore(cfg, || {
        let cell = Arc::new(BrokenCell::new(0));
        let reader = {
            let cell = cell.clone();
            Box::new(move || {
                let _ = cell.load();
            }) as Body
        };
        let writer = {
            let cell = cell.clone();
            Box::new(move || {
                cell.store(7);
            }) as Body
        };
        vec![reader, writer]
    });
    let failure = report.failure.expect("the race must be found");
    assert!(
        failure.message.contains("use-after-free"),
        "wrong failure: {failure:?}"
    );
}

/// Satellite: `ObsRegistry` slack drain. Two threads each record locally
/// and flush via `drain_into`; a checker thread waits for both and
/// asserts no increment was lost (the registry's counters are the shim
/// atomics, so every `fetch_add` is a schedule point).
#[test]
#[cfg_attr(miri, ignore)]
fn obs_slack_drain_loses_no_samples() {
    let cfg = Config { max_preemptions: 2, max_steps: 10_000, max_execs: 100_000 };
    let report = explore(cfg, || {
        let reg = Arc::new(ObsRegistry::new());
        let done = Arc::new(AtomicU64::new(0));
        let mut bodies: Vec<Body> = Vec::new();
        for _ in 0..2 {
            let reg = reg.clone();
            let done = done.clone();
            bodies.push(Box::new(move || {
                let mut win = SlackWindow::default();
                win.record(BoundKind::Mult, 0.25);
                win.record(BoundKind::Mult, 0.5);
                win.drain_into(&reg, 0);
                done.fetch_add(1, Ordering::SeqCst);
            }));
        }
        {
            let reg = reg.clone();
            let done = done.clone();
            bodies.push(Box::new(move || {
                while done.load(Ordering::SeqCst) < 2 {
                    simetra::sync::yield_now();
                }
                let n = reg.slack_count(0, BoundKind::Mult);
                assert_eq!(n, 4, "lost slack samples: {n} != 4");
            }));
        }
        bodies
    });
    assert!(report.failure.is_none(), "{:?}", report.failure);
    assert!(report.complete, "schedule space not exhausted: {report:?}");
}

/// Satellite: the server pool's queue stays FIFO under every explored
/// producer/consumer interleaving — a consumer never observes reordered
/// items, and the blocking `pop` never wedges (the livelock guard would
/// flag a schedule where it stops making progress).
#[test]
#[cfg_attr(miri, ignore)]
fn run_queue_is_fifo_under_the_model() {
    let cfg = Config { max_preemptions: 2, max_steps: 10_000, max_execs: 100_000 };
    let report = explore(cfg, || {
        let q = Arc::new(RunQueue::new());
        let producer = {
            let q = q.clone();
            Box::new(move || {
                q.push(1u64);
                q.push(2u64);
            }) as Body
        };
        let consumer = {
            let q = q.clone();
            Box::new(move || {
                let (a, _) = q.pop(POLL).expect("queue not stopped");
                let (b, _) = q.pop(POLL).expect("queue not stopped");
                assert_eq!((a, b), (1, 2), "reordered delivery");
            }) as Body
        };
        vec![producer, consumer]
    });
    assert!(report.failure.is_none(), "{:?}", report.failure);
    assert!(report.complete, "schedule space not exhausted: {report:?}");
}

/// Satellite: the `ServeHandle::stop` protocol in miniature — a
/// coordinator pushes work, flips the stop switch, and joins two workers.
/// Across all explored schedules no item may vanish: everything the
/// workers delivered plus everything `drain` recovered must equal what
/// was pushed, and a post-stop `pop` must refuse.
#[test]
#[cfg_attr(miri, ignore)]
fn run_queue_stop_joins_workers_without_losing_items() {
    let cfg = Config { max_preemptions: 2, max_steps: 20_000, max_execs: 150_000 };
    let report = explore(cfg, || {
        let q = Arc::new(RunQueue::new());
        let delivered = Arc::new(AtomicU64::new(0));
        let exited = Arc::new(AtomicU64::new(0));
        let mut bodies: Vec<Body> = Vec::new();
        for _ in 0..2 {
            let q = q.clone();
            let delivered = delivered.clone();
            let exited = exited.clone();
            bodies.push(Box::new(move || {
                while let Some((v, _)) = q.pop(POLL) {
                    delivered.fetch_add(v, Ordering::SeqCst);
                }
                exited.fetch_add(1, Ordering::SeqCst);
            }));
        }
        {
            let q = q.clone();
            let delivered = delivered.clone();
            let exited = exited.clone();
            bodies.push(Box::new(move || {
                q.push(7u64);
                q.push(9u64);
                q.stop();
                while exited.load(Ordering::SeqCst) < 2 {
                    simetra::sync::yield_now();
                }
                let leftover: u64 = q.drain().into_iter().sum();
                let total = delivered.load(Ordering::SeqCst) + leftover;
                assert_eq!(total, 16, "work lost across stop: {total} != 16");
                assert!(q.pop(POLL).is_none(), "pop after stop must refuse");
            }));
        }
        bodies
    });
    assert!(report.failure.is_none(), "{:?}", report.failure);
    assert!(report.complete, "schedule space not exhausted: {report:?}");
}
