//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The container this repo builds in has no crates.io access, so this
//! vendored crate provides exactly the subset of `anyhow`'s API the
//! workspace uses: [`Error`], [`Result`], the [`anyhow!`] / [`bail!`] /
//! [`ensure!`] macros, and the [`Context`] extension trait for `Result`
//! and `Option`. Semantics match real `anyhow` for this subset:
//!
//! - `Error` is a cheap message-carrying error that is `Send + Sync` and
//!   intentionally does **not** implement `std::error::Error`, so the
//!   blanket `From<E: std::error::Error>` conversion (what makes `?`
//!   work on `io::Result` etc.) does not conflict with the reflexive
//!   `From<Error> for Error`.
//! - `.context(..)` / `.with_context(..)` prefix the underlying error,
//!   rendering as `"context: cause"`.
//!
//! Swapping in the real crate later is a one-line Cargo.toml change.

use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A message-carrying error with an optional chain of context prefixes.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string() }
    }

    /// Prefix the error with additional context (newest first).
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// Attach context to the error of a `Result` or to a `None`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{context}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($msg:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($msg, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::Other, "disk on fire"))?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(e.to_string().contains("disk on fire"));
    }

    #[test]
    fn context_prefixes() {
        let e = io_fail().context("loading manifest").unwrap_err();
        assert_eq!(e.to_string(), "loading manifest: disk on fire");
        let n: Option<u32> = None;
        assert_eq!(n.context("missing").unwrap_err().to_string(), "missing");
    }

    #[test]
    fn macros_format_and_bail() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(f(3).unwrap_err().to_string(), "three is right out");
        assert_eq!(f(11).unwrap_err().to_string(), "x too big: 11");
        let owned: String = "boom".into();
        assert_eq!(anyhow!(owned).to_string(), "boom");
    }
}
